//! [`Basis`](super::Basis) implementations — the "which space does the
//! update rule run in" axis of the paper's factorization.
//!
//! - [`IdentityBasis`] — no rotation; the engine works in the original
//!   coordinates (AdamW, Adafactor).
//! - [`EigenBasis`] — the slowly-refreshed Kronecker-factor decomposition
//!   shared by SOAP and Shampoo. Two flavors: [`EigenFlavor::Rotation`]
//!   maintains orthonormal eigenvector bases `Q_L`/`Q_R` (SOAP, Algorithm 3
//!   + the Algorithm 4 QR power-iteration refresh), and
//!   [`EigenFlavor::InverseRoot`] maintains cached inverse roots
//!   `L^{-1/e}`/`R^{-1/e}` (Shampoo). Both support one-sided / max-dim-capped
//!   side selection, QR-power-iteration or warm-`eigh` refresh, and inline or
//!   async execution through the existing [`crate::precond::RefreshService`].
//! - [`GradSvdBasis`] — GaLore's projector: the eigenbasis of the *current*
//!   gradient's square factor (≡ its singular vectors at full rank),
//!   recomputed from scratch at the refresh frequency (§3 difference #1).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::state::StateMatrix;
use super::workspace::{Scratch, Workspace};
use super::{Basis, BasisState, StateLayout};
use crate::linalg::{eigh, eigh_warm, power_iter_refresh, roots::inv_root_from_eig, Matrix};
use crate::optim::hyper::{Hyper, RefreshMethod};
use crate::precond::{BasisHandle, BasisPayload, DistBasisPort, RefreshService};

/// Process-wide basis id counter: gives every refreshable basis a stable
/// per-layer tag for trace spans without threading layer indices through
/// construction. Observation-only — never touches the math.
static NEXT_BASIS_ID: AtomicU64 = AtomicU64::new(0);

fn next_basis_id() -> u64 {
    NEXT_BASIS_ID.fetch_add(1, Ordering::Relaxed)
}

/// Sample the whitening-quality metric on every k-th completed refresh
/// (1st, 1+k-th, …). Refresh-time only, telemetry-gated, so the allocating
/// matmuls never touch the steady-state step.
const WHITENING_SAMPLE_EVERY: u64 = 4;

/// Off-diagonal mass fraction of a square matrix: ‖offdiag(A)‖²_F / ‖A‖²_F.
/// 0 = perfectly diagonal (ideal whitening), → 1 = energy all off-diagonal.
fn offdiag_ratio(a: &Matrix) -> f64 {
    let n = a.rows.min(a.cols);
    let mut off = 0.0f64;
    let mut tot = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let x = a.at(i, j) as f64;
            tot += x * x;
            if i != j {
                off += x * x;
            }
        }
    }
    if tot > 0.0 {
        off / tot
    } else {
        0.0
    }
}

/// The trivial basis: the working space IS the original space.
#[derive(Default)]
pub struct IdentityBasis;

impl IdentityBasis {
    pub fn new() -> Self {
        Self
    }
}

impl Basis for IdentityBasis {
    fn begin_step(&mut self, _g: &Matrix, _t: u64, _ws: &mut Workspace) {}
    fn end_step(&mut self, _g: &Matrix, _t: u64, _ws: &mut Workspace) {}

    fn is_identity(&self) -> bool {
        true
    }

    fn project_into(&self, x: &Matrix, out: &mut Matrix, _scratch: &mut Scratch) {
        out.copy_from(x);
    }

    fn project_back_into(&self, x: &Matrix, out: &mut Matrix, _scratch: &mut Scratch) {
        out.copy_from(x);
    }

    fn state_bytes(&self) -> usize {
        0
    }

    fn export(&self) -> BasisState {
        BasisState { flags: Vec::new(), tensors: Vec::new() }
    }

    fn import(
        &mut self,
        _flags: &[f32],
        _it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()> {
        Ok(())
    }

    fn layout(&self) -> StateLayout {
        StateLayout::Bare
    }
}

/// What the periodic refresh of an [`EigenBasis`] produces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EigenFlavor {
    /// Orthonormal eigenvector bases; `project` = `Q_Lᵀ X Q_R`,
    /// `project_back` = `Q_L X Q_Rᵀ` (SOAP).
    Rotation,
    /// Cached inverse roots; `project` = `L^{-1/e} X R^{-1/e}` applies the
    /// whole Shampoo preconditioner at once and `project_back` is the
    /// identity (the sandwich is self-inverse-free: there is no "back").
    InverseRoot,
}

/// The slowly-rotating Kronecker-factor basis shared by SOAP and Shampoo.
///
/// Maintains the factor EMAs `L ← β_s L + (1−β_s) GGᵀ` and
/// `R ← β_s R + (1−β_s) GᵀG` and, every `f` steps (at this layer's phase),
/// refreshes the published matrices per [`EigenFlavor`]. Refreshes run
/// inline or on the background [`RefreshService`] (`attach_async`), adopting
/// the published pair tear-free through a [`BasisHandle`].
pub struct EigenBasis {
    h: Hyper,
    pub flavor: EigenFlavor,
    /// Kronecker-factor EMAs. `None` = that side is identity (one-sided /
    /// max-dim-capped; Rotation flavor only — InverseRoot keeps both).
    /// Stored per [`Hyper::state_dtype`] (f32 or bf16).
    pub l: Option<StateMatrix>,
    pub r: Option<StateMatrix>,
    /// Rotation: eigenvector bases `Q_L`/`Q_R` (None until first init).
    /// InverseRoot: cached `L^{-1/e}`/`R^{-1/e}` (start as identity).
    pub left_q: Option<Matrix>,
    pub right_q: Option<Matrix>,
    /// InverseRoot only: warm-start eigenvector caches for `eigh_warm`.
    pub l_vecs: Option<Matrix>,
    pub r_vecs: Option<Matrix>,
    pub initialized: bool,
    refresh_secs: f64,
    /// Async refresh plumbing (`None` ⇒ inline refreshes).
    service: Option<Arc<RefreshService>>,
    handle: Option<Arc<BasisHandle>>,
    /// Distributed refresh ownership. `None` = not distributed (every rank
    /// refreshes locally); `Some(true)` = this rank runs the refresh and
    /// mirror-publishes it for broadcast; `Some(false)` = a peer owns the
    /// refresh and this basis only adopts broadcast publications.
    dist_owned: Option<bool>,
    /// Highest published version this basis may adopt. Shared with the
    /// distributed executor, which raises it only after a publication has
    /// been broadcast to (or received from) every peer — so no rank's active
    /// basis can run ahead of the others within a step.
    adopt_cap: Option<Arc<AtomicU64>>,
    pub adopted_version: u64,
    /// Step whose factors back the ACTIVE basis (staleness = t − this).
    pub basis_step: u64,
    /// Stable id tagging this basis's refresh spans (`args.layer` in the
    /// Chrome trace). Assigned once at construction from a global counter.
    trace_id: u64,
    /// Completed refreshes adopted by THIS basis (init + inline + async
    /// adoptions) — drives the every-k-th whitening sample cadence.
    refresh_count: u64,
    /// Latest whitening-quality sample: off-diagonal mass fraction of the
    /// rotated second moment `QᵀLQ` (ROADMAP metric). `None` until telemetry
    /// is enabled and a sampled refresh has run.
    whitening: Option<f64>,
}

impl EigenBasis {
    /// SOAP-style rotation basis. §7.1 one-sided rotates only the smaller
    /// side; implementation detail 3: dims over `max_precond_dim` keep
    /// `Q = I`.
    pub fn rotation(rows: usize, cols: usize, h: &Hyper) -> Self {
        let mut left = rows <= h.max_precond_dim;
        let mut right = cols <= h.max_precond_dim;
        if h.one_sided {
            if rows <= cols {
                right = false;
            } else {
                left = false;
            }
        }
        Self {
            h: h.clone(),
            flavor: EigenFlavor::Rotation,
            l: left.then(|| StateMatrix::zeros(rows, rows, h.state_dtype)),
            r: right.then(|| StateMatrix::zeros(cols, cols, h.state_dtype)),
            left_q: None,
            right_q: None,
            l_vecs: None,
            r_vecs: None,
            initialized: false,
            refresh_secs: 0.0,
            service: None,
            handle: None,
            dist_owned: None,
            adopt_cap: None,
            adopted_version: 0,
            basis_step: 0,
            trace_id: next_basis_id(),
            refresh_count: 0,
            whitening: None,
        }
    }

    /// Shampoo-style inverse-root basis: both sides always preconditioned
    /// (Shampoo preconditions 1-D parameters too), roots start at identity.
    pub fn inverse_root(rows: usize, cols: usize, h: &Hyper) -> Self {
        Self {
            h: h.clone(),
            flavor: EigenFlavor::InverseRoot,
            l: Some(StateMatrix::zeros(rows, rows, h.state_dtype)),
            r: Some(StateMatrix::zeros(cols, cols, h.state_dtype)),
            left_q: Some(Matrix::eye(rows)),
            right_q: Some(Matrix::eye(cols)),
            l_vecs: None,
            r_vecs: None,
            initialized: false,
            refresh_secs: 0.0,
            service: None,
            handle: None,
            dist_owned: None,
            adopt_cap: None,
            adopted_version: 0,
            basis_step: 0,
            trace_id: next_basis_id(),
            refresh_count: 0,
            whitening: None,
        }
    }

    /// Bookkeeping shared by every path that installs a fresh basis: advance
    /// the refresh counter and, when telemetry is on, sample the whitening
    /// metric on the every-k-th cadence.
    fn note_refresh_completed(&mut self) {
        self.refresh_count += 1;
        if crate::telemetry::enabled() && self.refresh_count % WHITENING_SAMPLE_EVERY == 1 {
            self.sample_whitening();
        }
    }

    /// Whitening quality: rotate the factor EMA into the active basis and
    /// measure the off-diagonal mass of `QᵀLQ`. A perfectly whitened layer is
    /// diagonal (Q exactly L's eigenbasis); basis staleness shows up as mass
    /// leaking off the diagonal. The allocating matmuls are fine here — this
    /// runs only at (sampled) refresh time, never in the steady-state step.
    fn sample_whitening(&mut self) {
        let (p, q) = match self.flavor {
            EigenFlavor::Rotation => match (&self.l, &self.left_q) {
                (Some(l), Some(ql)) => (l, ql),
                _ => match (&self.r, &self.right_q) {
                    (Some(r), Some(qr)) => (r, qr),
                    _ => return,
                },
            },
            // InverseRoot: `left_q` holds `L^{-1/e}`, not an orthonormal
            // basis — rotate with the warm-start eigenvector cache instead.
            EigenFlavor::InverseRoot => match (&self.l, &self.l_vecs) {
                (Some(l), Some(vl)) => (l, vl),
                _ => return,
            },
        };
        // Telemetry-only decode: refresh-time, never the steady-state step.
        let rotated = q.matmul_tn(&p.to_matrix().matmul(q));
        self.whitening = Some(offdiag_ratio(&rotated));
    }

    /// First-step initialization (Rotation): set L/R from the first gradient
    /// and take a full eigendecomposition for the starting basis, as in the
    /// official implementation.
    fn init_rotation(&mut self, g: &Matrix, t: u64) {
        let _span = crate::telemetry::span_layer("refresh.init", "refresh", self.trace_id);
        let t0 = Instant::now();
        // Decompose the exact f32 gram, then store it at the state dtype —
        // the eigenbasis itself stays full precision either way (and is
        // checkpointed separately, so resume sees the same basis).
        if let Some(l) = &mut self.l {
            let gram = g.matmul_nt(g);
            let (_, v) = eigh(&gram);
            l.assign_from(&gram);
            self.left_q = Some(v);
        }
        if let Some(r) = &mut self.r {
            let gram = g.matmul_tn(g);
            let (_, v) = eigh(&gram);
            r.assign_from(&gram);
            self.right_q = Some(v);
        }
        self.initialized = true;
        self.basis_step = t;
        self.refresh_secs += t0.elapsed().as_secs_f64();
        self.note_refresh_completed();
    }

    /// The Rotation refresh math (Algorithm 4 power-iteration + QR, or warm
    /// `eigh`), as a pure function of factor/basis snapshots so the inline
    /// and background paths run IDENTICAL code.
    fn compute_rotation_refresh(
        method: RefreshMethod,
        l: Option<&Matrix>,
        r: Option<&Matrix>,
        ql: Option<&Matrix>,
        qr: Option<&Matrix>,
    ) -> (Option<Matrix>, Option<Matrix>) {
        let one_side = |p: Option<&Matrix>, q: Option<&Matrix>| -> Option<Matrix> {
            match method {
                RefreshMethod::QrPowerIteration => match (p, q) {
                    (Some(p), Some(q)) => Some(power_iter_refresh(p, q)),
                    _ => None,
                },
                // Warm-start from the current basis (§Perf): the EMA'd
                // factors drift slowly between refreshes, so the previous
                // eigenvectors are an excellent initial guess.
                RefreshMethod::Eigh => p.map(|p| match q {
                    Some(prev) => eigh_warm(p, prev).1,
                    None => eigh(p).1,
                }),
            }
        };
        (one_side(l, ql), one_side(r, qr))
    }

    /// The InverseRoot refresh math, pure in the bias-corrected factor
    /// snapshots. Returns `(l_inv, r_inv, l_vecs, r_vecs)`.
    fn compute_roots(
        lh: &Matrix,
        rh: &Matrix,
        prev_l: Option<&Matrix>,
        prev_r: Option<&Matrix>,
        e: f32,
        eps: f32,
    ) -> (Matrix, Matrix, Matrix, Matrix) {
        let (wl, vl) = match prev_l {
            Some(prev) => eigh_warm(lh, prev),
            None => eigh(lh),
        };
        let (wr, vr) = match prev_r {
            Some(prev) => eigh_warm(rh, prev),
            None => eigh(rh),
        };
        let l_inv = inv_root_from_eig(&wl, &vl, e, eps);
        let r_inv = inv_root_from_eig(&wr, &vr, e, eps);
        (l_inv, r_inv, vl, vr)
    }

    /// Bias-corrected factor snapshots at step `t` (InverseRoot flavor).
    fn corrected_factors(&self, t: u64) -> (Matrix, Matrix) {
        let bc = 1.0 - self.h.shampoo_beta.powi(t as i32);
        (
            self.l.as_ref().expect("inverse-root basis has L").to_matrix().scale(1.0 / bc),
            self.r.as_ref().expect("inverse-root basis has R").to_matrix().scale(1.0 / bc),
        )
    }

    /// Chaos hook for the `eigh-fail` fault clause: when this basis is the
    /// plan's target at step `t`, poison the freshly computed payload with
    /// NaN so the rejection guards must fire. No-op without an armed plan.
    fn maybe_poison_refresh(trace_id: u64, payload: &mut BasisPayload, t: u64) {
        if crate::fault::active().is_some_and(|f| f.eigh_poison(trace_id, t)) {
            crate::telemetry::metrics::fault_injected_total().inc();
            let m = [
                &mut payload.left,
                &mut payload.right,
                &mut payload.left_aux,
                &mut payload.right_aux,
            ]
            .into_iter()
            .find_map(|m| m.as_mut());
            if let Some(m) = m {
                m.data[0] = f32::NAN;
            }
        }
    }

    /// Periodic refresh, executed inline (synchronously). Returns whether a
    /// fresh basis was actually installed: a non-finite factor gram or a
    /// non-finite decomposition result is rejected — the previous basis
    /// stays active (SOAP's stale-basis grace, paper §1/Fig. 1 is exactly
    /// the license for this) and `soap_basis_rejected_total` is bumped.
    fn refresh_inline(&mut self, t: u64) -> bool {
        let _span = crate::telemetry::span_layer("refresh.inline", "refresh", self.trace_id);
        let t0 = Instant::now();
        let finite = |m: &Matrix| m.data.iter().all(|x| x.is_finite());
        let finite_opt = |m: &Option<StateMatrix>| m.as_ref().map_or(true, |m| m.is_finite());
        let installed = match self.flavor {
            EigenFlavor::Rotation => {
                if !(finite_opt(&self.l) && finite_opt(&self.r)) {
                    // Poisoned gram: don't hand NaN to the decomposition at
                    // all — it cannot produce a usable basis.
                    false
                } else {
                    // Refresh-time decode of the factor EMAs (allocating is
                    // fine off the steady-state step).
                    let l = self.l.as_ref().map(|m| m.to_matrix());
                    let r = self.r.as_ref().map(|m| m.to_matrix());
                    let (left, right) = Self::compute_rotation_refresh(
                        self.h.refresh,
                        l.as_ref(),
                        r.as_ref(),
                        self.left_q.as_ref(),
                        self.right_q.as_ref(),
                    );
                    let mut payload =
                        BasisPayload { left, right, left_aux: None, right_aux: None };
                    Self::maybe_poison_refresh(self.trace_id, &mut payload, t);
                    if payload.is_finite() {
                        if let Some(q) = payload.left {
                            self.left_q = Some(q);
                        }
                        if let Some(q) = payload.right {
                            self.right_q = Some(q);
                        }
                        true
                    } else {
                        false
                    }
                }
            }
            EigenFlavor::InverseRoot => {
                // Per-factor exponent −1/e: e = 4 is original Shampoo, e = 2
                // the Anil et al / Morwani et al power-1/2 variant, e = 2.5
                // the paper's DistributedShampoo default (Appendix A).
                let (lh, rh) = self.corrected_factors(t);
                if !(finite(&lh) && finite(&rh)) {
                    false
                } else {
                    let (l_inv, r_inv, vl, vr) = Self::compute_roots(
                        &lh,
                        &rh,
                        self.l_vecs.as_ref(),
                        self.r_vecs.as_ref(),
                        self.h.shampoo_exponent,
                        self.h.shampoo_eps,
                    );
                    let mut payload = BasisPayload {
                        left: Some(l_inv),
                        right: Some(r_inv),
                        left_aux: Some(vl),
                        right_aux: Some(vr),
                    };
                    Self::maybe_poison_refresh(self.trace_id, &mut payload, t);
                    if payload.is_finite() {
                        self.left_q = payload.left;
                        self.right_q = payload.right;
                        self.l_vecs = payload.left_aux;
                        self.r_vecs = payload.right_aux;
                        true
                    } else {
                        false
                    }
                }
            }
        };
        self.refresh_secs += t0.elapsed().as_secs_f64();
        if installed {
            self.basis_step = t;
            self.note_refresh_completed();
        } else {
            crate::telemetry::metrics::basis_rejected_total().inc();
        }
        installed
    }

    /// Async mode: swap in the newest published basis, if any. One atomic
    /// load on the no-news path; the payload pair is adopted wholesale, so a
    /// torn basis is impossible (see `precond::handle`).
    fn adopt_published(&mut self) {
        let Some(handle) = &self.handle else { return };
        if handle.version() <= self.adopted_version {
            return;
        }
        if let Some(published) = handle.latest() {
            if published.version > self.adopted_version {
                // Distributed: never adopt a publication the executor hasn't
                // finished broadcasting — peers must see it the same step.
                if let Some(cap) = &self.adopt_cap {
                    if published.version > cap.load(Ordering::Acquire) {
                        return;
                    }
                }
                match self.flavor {
                    EigenFlavor::Rotation => {
                        if let Some(q) = &published.payload.left {
                            self.left_q = Some(q.clone());
                        }
                        if let Some(q) = &published.payload.right {
                            self.right_q = Some(q.clone());
                        }
                    }
                    EigenFlavor::InverseRoot => {
                        let p = &published.payload;
                        if let (Some(li), Some(ri)) = (&p.left, &p.right) {
                            self.left_q = Some(li.clone());
                            self.right_q = Some(ri.clone());
                        }
                        self.l_vecs = p.left_aux.clone().or_else(|| self.l_vecs.take());
                        self.r_vecs = p.right_aux.clone().or_else(|| self.r_vecs.take());
                    }
                }
                self.adopted_version = published.version;
                self.basis_step = published.snapshot_step;
                self.note_refresh_completed();
            }
        }
    }

    /// Async mode: snapshot the factor EMAs + current basis and hand the
    /// refresh to the service. Skipped (not queued) while a previous refresh
    /// is still in flight, so a slow decomposition sheds load instead of
    /// building a backlog.
    fn enqueue_refresh(&self, service: &Arc<RefreshService>, handle: &Arc<BasisHandle>, t: u64) {
        if !handle.try_begin_refresh() {
            // Shed, not queued: this is the single load-shedding point the
            // refresh-service introspection counts.
            if crate::telemetry::enabled() {
                crate::telemetry::metrics::refresh_shed_total().inc();
            }
            return;
        }
        let trace_id = self.trace_id;
        match self.flavor {
            EigenFlavor::Rotation => {
                let method = self.h.refresh;
                let l = self.l.as_ref().map(|m| m.to_matrix());
                let r = self.r.as_ref().map(|m| m.to_matrix());
                let ql = self.left_q.clone();
                let qr = self.right_q.clone();
                service.enqueue(
                    Arc::clone(handle),
                    t,
                    Box::new(move || {
                        let _span =
                            crate::telemetry::span_layer("refresh.bg", "refresh", trace_id);
                        let (left, right) = Self::compute_rotation_refresh(
                            method,
                            l.as_ref(),
                            r.as_ref(),
                            ql.as_ref(),
                            qr.as_ref(),
                        );
                        let mut payload =
                            BasisPayload { left, right, left_aux: None, right_aux: None };
                        // The service's publish gate rejects the poisoned
                        // payload, exercising the async guard path.
                        Self::maybe_poison_refresh(trace_id, &mut payload, t);
                        payload
                    }),
                );
            }
            EigenFlavor::InverseRoot => {
                let (lh, rh) = self.corrected_factors(t);
                let prev_l = self.l_vecs.clone();
                let prev_r = self.r_vecs.clone();
                let e = self.h.shampoo_exponent;
                let eps = self.h.shampoo_eps;
                service.enqueue(
                    Arc::clone(handle),
                    t,
                    Box::new(move || {
                        let _span =
                            crate::telemetry::span_layer("refresh.bg", "refresh", trace_id);
                        let (l_inv, r_inv, vl, vr) = Self::compute_roots(
                            &lh,
                            &rh,
                            prev_l.as_ref(),
                            prev_r.as_ref(),
                            e,
                            eps,
                        );
                        let mut payload = BasisPayload {
                            left: Some(l_inv),
                            right: Some(r_inv),
                            left_aux: Some(vl),
                            right_aux: Some(vr),
                        };
                        Self::maybe_poison_refresh(trace_id, &mut payload, t);
                        payload
                    }),
                );
            }
        }
    }

    /// Refresh now, routing through the service when attached. Under
    /// distributed ownership a non-owning rank skips the work entirely (it
    /// adopts the owner's broadcast instead), while the owner's inline path
    /// mirror-publishes the fresh basis so the executor can ship it.
    fn refresh_or_enqueue(&mut self, t: u64) {
        if self.dist_owned == Some(false) {
            return;
        }
        if let (Some(service), Some(handle)) = (self.service.clone(), self.handle.clone()) {
            // Worker-panic fallback: if the last background refresh for this
            // layer blew up, run this one inline instead of re-enqueueing
            // onto the pool — the run keeps its refresh cadence even with a
            // pathological layer. The latch clears on take, so a one-off
            // panic costs exactly one inline refresh.
            if !handle.take_worker_panic() {
                self.enqueue_refresh(&service, &handle, t);
                return;
            }
        }
        let installed = self.refresh_inline(t);
        if installed && self.dist_owned == Some(true) {
            if let Some(handle) = self.handle.clone() {
                let payload = BasisPayload {
                    left: self.left_q.clone(),
                    right: self.right_q.clone(),
                    left_aux: self.l_vecs.clone(),
                    right_aux: self.r_vecs.clone(),
                };
                // The inline write above already installed the basis;
                // fast-forwarding `adopted_version` stops this rank
                // from re-adopting its own publication. A rejected
                // refresh publishes nothing: every rank keeps the
                // previous basis, so the mesh stays in lockstep.
                self.adopted_version = handle.publish(payload, t);
            }
        }
    }
}

impl Basis for EigenBasis {
    fn begin_step(&mut self, g: &Matrix, t: u64, ws: &mut Workspace) {
        // Pure-Adam ramp: no statistics, no init, no refresh — the basis
        // stays in its pre-init state (identity projection) and the first
        // post-warmup gradient seeds it fresh.
        if t <= self.h.adam_warmup_steps {
            return;
        }
        match self.flavor {
            EigenFlavor::Rotation => {
                if !self.initialized {
                    self.init_rotation(g, t);
                }
                // Pick up any basis the background service published since
                // the last step — before projecting, so it's used now.
                self.adopt_published();
            }
            EigenFlavor::InverseRoot => {
                // Factor EMAs first (Shampoo updates them ahead of the
                // direction — the roots computed this step may use them).
                // `GGᵀ` and `GᵀG` share `ws.factor` serially: the serial
                // into-kernels are bitwise identical to the parallel
                // allocating path and cost zero steady-state allocations.
                g.matmul_nt_into(g, &mut ws.factor, &mut ws.scratch.pack);
                self.l.as_mut().unwrap().ema_inplace(&ws.factor, self.h.shampoo_beta);
                g.matmul_tn_into(g, &mut ws.factor);
                self.r.as_mut().unwrap().ema_inplace(&ws.factor, self.h.shampoo_beta);
                self.adopt_published();
                // The first recompute always runs inline so the roots are
                // never identity-only.
                if !self.initialized {
                    self.refresh_inline(t);
                    self.initialized = true;
                } else if self.h.is_refresh_step(t) {
                    self.refresh_or_enqueue(t);
                }
            }
        }
    }

    fn end_step(&mut self, g: &Matrix, t: u64, ws: &mut Workspace) {
        if self.flavor != EigenFlavor::Rotation {
            return;
        }
        if t <= self.h.adam_warmup_steps {
            return;
        }
        // Factor EMAs + periodic basis refresh AFTER the step, per Alg 3.
        if let Some(l) = &mut self.l {
            g.matmul_nt_into(g, &mut ws.factor, &mut ws.scratch.pack);
            l.ema_inplace(&ws.factor, self.h.shampoo_beta);
        }
        if let Some(r) = &mut self.r {
            g.matmul_tn_into(g, &mut ws.factor);
            r.ema_inplace(&ws.factor, self.h.shampoo_beta);
        }
        if self.h.is_refresh_step(t) {
            self.refresh_or_enqueue(t);
        }
    }

    fn project_into(&self, x: &Matrix, out: &mut Matrix, scratch: &mut Scratch) {
        match self.flavor {
            // Rotate into the eigenbasis: Q_Lᵀ · X · Q_R (identity sides
            // skipped).
            EigenFlavor::Rotation => match (&self.left_q, &self.right_q) {
                (Some(ql), Some(qr)) => {
                    ql.matmul_tn_into(x, &mut scratch.tmp);
                    scratch.tmp.matmul_into(qr, out);
                }
                (Some(ql), None) => ql.matmul_tn_into(x, out),
                (None, Some(qr)) => x.matmul_into(qr, out),
                (None, None) => out.copy_from(x),
            },
            // Apply the whole preconditioner: L^{-1/e} · X · R^{-1/e}.
            EigenFlavor::InverseRoot => {
                self.left_q.as_ref().unwrap().matmul_into(x, &mut scratch.tmp);
                scratch.tmp.matmul_into(self.right_q.as_ref().unwrap(), out);
            }
        }
    }

    fn project_back_into(&self, x: &Matrix, out: &mut Matrix, scratch: &mut Scratch) {
        match self.flavor {
            // Rotate back: Q_L · X · Q_Rᵀ.
            EigenFlavor::Rotation => {
                let Scratch { tmp, pack } = scratch;
                match (&self.left_q, &self.right_q) {
                    (Some(ql), Some(qr)) => {
                        ql.matmul_into(x, tmp);
                        tmp.matmul_nt_into(qr, out, pack);
                    }
                    (Some(ql), None) => ql.matmul_into(x, out),
                    (None, Some(qr)) => x.matmul_nt_into(qr, out, pack),
                    (None, None) => out.copy_from(x),
                }
            }
            EigenFlavor::InverseRoot => out.copy_from(x),
        }
    }

    fn refresh_seconds(&self) -> f64 {
        self.refresh_secs
    }

    fn attach_async(&mut self, service: &Arc<RefreshService>) -> bool {
        if self.l.is_none() && self.r.is_none() {
            return false; // both sides identity ⇒ nothing to refresh
        }
        self.service = Some(Arc::clone(service));
        self.handle = Some(Arc::new(BasisHandle::new()));
        self.adopted_version = 0;
        true
    }

    fn attach_dist(&mut self, owned: bool) -> Vec<DistBasisPort> {
        if self.l.is_none() && self.r.is_none() {
            return Vec::new(); // both sides identity ⇒ nothing to broadcast
        }
        // Reuse the async-attached handle when present; otherwise the inline
        // path still needs one as the broadcast mailbox.
        let handle = match &self.handle {
            Some(h) => Arc::clone(h),
            None => {
                let h = Arc::new(BasisHandle::new());
                self.handle = Some(Arc::clone(&h));
                h
            }
        };
        let cap = Arc::new(AtomicU64::new(handle.version()));
        self.adopt_cap = Some(Arc::clone(&cap));
        self.dist_owned = Some(owned);
        vec![DistBasisPort { handle, adopt_cap: cap }]
    }

    fn dist_mid_step_sync(&self, t: u64) -> bool {
        // Shampoo's inline periodic refresh feeds the SAME step's update, so
        // a distributed run must exchange the owner's fresh roots mid-step.
        // Every term below is replicated state — all ranks agree.
        self.flavor == EigenFlavor::InverseRoot
            && self.dist_owned.is_some()
            && self.service.is_none()
            && self.initialized
            && t > self.h.adam_warmup_steps
            && self.h.is_refresh_step(t)
    }

    fn adopt_pending(&mut self) {
        self.adopt_published();
    }

    fn basis_snapshot_step(&self) -> Option<u64> {
        match self.flavor {
            EigenFlavor::Rotation => (self.initialized
                && (self.left_q.is_some() || self.right_q.is_some()))
            .then_some(self.basis_step),
            EigenFlavor::InverseRoot => self.initialized.then_some(self.basis_step),
        }
    }

    fn whitening_offdiag(&self) -> Option<f64> {
        self.whitening
    }

    fn state_bytes(&self) -> usize {
        let opt = |x: &Option<Matrix>| x.as_ref().map(|m| m.numel()).unwrap_or(0);
        let opt_s = |x: &Option<StateMatrix>| x.as_ref().map(|m| m.state_bytes()).unwrap_or(0);
        // The warm-start eigenvector caches ARE held state (the pre-refactor
        // Shampoo under-reported by omitting them — §7.2 accounting). The
        // factor EMAs report their actual storage width; the basis/root/vec
        // caches are always f32.
        opt_s(&self.l)
            + opt_s(&self.r)
            + (opt(&self.left_q) + opt(&self.right_q) + opt(&self.l_vecs) + opt(&self.r_vecs))
                * 4
    }

    fn export(&self) -> BasisState {
        match self.flavor {
            EigenFlavor::Rotation => {
                let flags = vec![
                    self.initialized as u8 as f32,
                    self.l.is_some() as u8 as f32,
                    self.r.is_some() as u8 as f32,
                    // f32 is exact up to 2^24 steps — far beyond our runs.
                    self.basis_step as f32,
                ];
                let mut tensors = Vec::new();
                // Factor EMAs decode to the f32 wire; bf16-stored values lie
                // on the bf16 grid, so re-encoding on import round-trips the
                // exact stored words.
                for opt in [&self.l, &self.r] {
                    if let Some(x) = opt {
                        tensors.push(x.to_matrix());
                    }
                }
                for opt in [&self.left_q, &self.right_q] {
                    if let Some(x) = opt {
                        tensors.push(x.clone());
                    }
                }
                BasisState { flags, tensors }
            }
            EigenFlavor::InverseRoot => {
                // Warm-start eigenvector caches ride along (has_vecs flag)
                // so a restored run's next refresh warm-starts exactly like
                // the uninterrupted run's — required for bitwise resume.
                let has_vecs = self.l_vecs.is_some() && self.r_vecs.is_some();
                let mut tensors = vec![
                    self.l.as_ref().unwrap().to_matrix(),
                    self.r.as_ref().unwrap().to_matrix(),
                    self.left_q.clone().unwrap(),
                    self.right_q.clone().unwrap(),
                ];
                if has_vecs {
                    tensors.push(self.l_vecs.clone().unwrap());
                    tensors.push(self.r_vecs.clone().unwrap());
                }
                BasisState {
                    flags: vec![
                        self.initialized as u8 as f32,
                        self.basis_step as f32,
                        has_vecs as u8 as f32,
                    ],
                    tensors,
                }
            }
        }
    }

    fn import(
        &mut self,
        flags: &[f32],
        it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()> {
        // Refreshes enqueued before the restore were computed from discarded
        // factors; drain them, then skip every pre-restore publication.
        if let (Some(service), Some(handle)) = (&self.service, &self.handle) {
            service.wait_idle();
            self.adopted_version = handle.version();
        }
        let mut next = |what: &str| {
            it.next().ok_or_else(|| anyhow::anyhow!("basis state missing {what}"))
        };
        match self.flavor {
            EigenFlavor::Rotation => {
                anyhow::ensure!(flags.len() == 4, "rotation basis flags malformed");
                self.initialized = flags[0] != 0.0;
                let has_l = flags[1] != 0.0;
                let has_r = flags[2] != 0.0;
                self.basis_step = flags[3] as u64;
                self.l = if has_l {
                    Some(StateMatrix::from_matrix(&next("l")?, self.h.state_dtype))
                } else {
                    None
                };
                self.r = if has_r {
                    Some(StateMatrix::from_matrix(&next("r")?, self.h.state_dtype))
                } else {
                    None
                };
                if self.initialized {
                    self.left_q = if has_l { Some(next("ql")?) } else { None };
                    self.right_q = if has_r { Some(next("qr")?) } else { None };
                }
            }
            EigenFlavor::InverseRoot => {
                anyhow::ensure!(flags.len() == 3, "inverse-root basis flags malformed");
                self.initialized = flags[0] != 0.0;
                self.basis_step = flags[1] as u64;
                self.l = Some(StateMatrix::from_matrix(&next("l")?, self.h.state_dtype));
                self.r = Some(StateMatrix::from_matrix(&next("r")?, self.h.state_dtype));
                self.left_q = Some(next("l_inv")?);
                self.right_q = Some(next("r_inv")?);
                if flags[2] != 0.0 {
                    self.l_vecs = Some(next("l_vecs")?);
                    self.r_vecs = Some(next("r_vecs")?);
                } else {
                    // Legacy row without warm caches: the next refresh
                    // cold-starts its eigh (pre-refactor behavior).
                    self.l_vecs = None;
                    self.r_vecs = None;
                }
            }
        }
        Ok(())
    }

    fn layout(&self) -> StateLayout {
        match self.flavor {
            EigenFlavor::Rotation => StateLayout::BasisMid,
            EigenFlavor::InverseRoot => StateLayout::InverseRoot,
        }
    }
}

/// GaLore's projector (Zhao et al. 2024a, full-rank): the eigenbasis of the
/// CURRENT gradient's square factor, smaller side only, recomputed from
/// scratch every `f` steps. For the full-rank square projector the left
/// singular vectors of `G` are the eigenvectors of `GGᵀ`, so the basis comes
/// from the Jacobi `eigh` of the square factor (no general SVD needed).
pub struct GradSvdBasis {
    h: Hyper,
    /// Projection matrix P (k×k on the smaller side); `None` until the
    /// first step.
    pub p: Option<Matrix>,
    /// Project the left side (true) or the right side (false).
    pub left: bool,
    refresh_secs: f64,
}

impl GradSvdBasis {
    pub fn new(rows: usize, cols: usize, h: &Hyper) -> Self {
        Self { h: h.clone(), p: None, left: rows <= cols, refresh_secs: 0.0 }
    }
}

impl Basis for GradSvdBasis {
    fn begin_step(&mut self, g: &Matrix, t: u64, _ws: &mut Workspace) {
        // Basis refresh from the CURRENT gradient (§3 difference #1), at
        // this layer's staggered phase. Refresh-time only — the allocating
        // parallel matmuls are the right tool here.
        if self.p.is_none() || self.h.is_refresh_step(t) {
            let t0 = Instant::now();
            let factor = if self.left { g.matmul_nt(g) } else { g.matmul_tn(g) };
            let (_, vecs) = eigh(&factor);
            self.p = Some(vecs);
            // NOTE: the engine's momentum is deliberately NOT re-rotated
            // (§3 difference #2).
            self.refresh_secs += t0.elapsed().as_secs_f64();
        }
    }

    fn end_step(&mut self, _g: &Matrix, _t: u64, _ws: &mut Workspace) {}

    fn project_into(&self, x: &Matrix, out: &mut Matrix, _scratch: &mut Scratch) {
        match (&self.p, self.left) {
            (Some(p), true) => p.matmul_tn_into(x, out),
            (Some(p), false) => x.matmul_into(p, out),
            (None, _) => out.copy_from(x),
        }
    }

    fn project_back_into(&self, x: &Matrix, out: &mut Matrix, scratch: &mut Scratch) {
        match (&self.p, self.left) {
            (Some(p), true) => p.matmul_into(x, out),
            (Some(p), false) => x.matmul_nt_into(p, out, &mut scratch.pack),
            (None, _) => out.copy_from(x),
        }
        // GaLore's update scale α rides with the projection (appendix B;
        // 1.0 for the full-rank version — bitwise a no-op then).
        out.scale_inplace(self.h.galore_scale);
    }

    fn refresh_seconds(&self) -> f64 {
        self.refresh_secs
    }

    fn state_bytes(&self) -> usize {
        self.p.as_ref().map(|p| p.numel()).unwrap_or(0) * 4
    }

    fn export(&self) -> BasisState {
        BasisState {
            flags: vec![self.p.is_some() as u8 as f32],
            tensors: self.p.clone().into_iter().collect(),
        }
    }

    fn import(
        &mut self,
        flags: &[f32],
        it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(flags.len() == 1, "grad-svd basis flags malformed");
        self.p = if flags[0] != 0.0 {
            Some(it.next().ok_or_else(|| anyhow::anyhow!("missing p"))?)
        } else {
            None
        };
        Ok(())
    }

    fn layout(&self) -> StateLayout {
        StateLayout::BasisLast
    }
}

/// Closed set of shipped bases, so composed optimizers are a single concrete
/// type (`DynComposed`) while [`Basis`] stays open for downstream impls.
// One value per model layer; the variant-size spread (EigenBasis vs the
// zero-sized identity) is irrelevant at that cardinality.
#[allow(clippy::large_enum_variant)]
pub enum AnyBasis {
    Identity(IdentityBasis),
    Eigen(EigenBasis),
    GradSvd(GradSvdBasis),
    /// Per-mode eigenbasis for rank-3+ tensor parameters.
    TensorEigen(super::tensor_basis::TensorEigenBasis),
}

impl AnyBasis {
    pub fn as_eigen(&self) -> Option<&EigenBasis> {
        match self {
            AnyBasis::Eigen(b) => Some(b),
            _ => None,
        }
    }

    pub fn as_grad_svd(&self) -> Option<&GradSvdBasis> {
        match self {
            AnyBasis::GradSvd(b) => Some(b),
            _ => None,
        }
    }

    pub fn as_tensor_eigen(&self) -> Option<&super::tensor_basis::TensorEigenBasis> {
        match self {
            AnyBasis::TensorEigen(b) => Some(b),
            _ => None,
        }
    }
}

impl Basis for AnyBasis {
    fn begin_step(&mut self, g: &Matrix, t: u64, ws: &mut Workspace) {
        match self {
            AnyBasis::Identity(b) => b.begin_step(g, t, ws),
            AnyBasis::Eigen(b) => b.begin_step(g, t, ws),
            AnyBasis::GradSvd(b) => b.begin_step(g, t, ws),
            AnyBasis::TensorEigen(b) => b.begin_step(g, t, ws),
        }
    }

    fn end_step(&mut self, g: &Matrix, t: u64, ws: &mut Workspace) {
        match self {
            AnyBasis::Identity(b) => b.end_step(g, t, ws),
            AnyBasis::Eigen(b) => b.end_step(g, t, ws),
            AnyBasis::GradSvd(b) => b.end_step(g, t, ws),
            AnyBasis::TensorEigen(b) => b.end_step(g, t, ws),
        }
    }

    fn is_identity(&self) -> bool {
        matches!(self, AnyBasis::Identity(_))
    }

    fn project_into(&self, x: &Matrix, out: &mut Matrix, scratch: &mut Scratch) {
        match self {
            AnyBasis::Identity(b) => b.project_into(x, out, scratch),
            AnyBasis::Eigen(b) => b.project_into(x, out, scratch),
            AnyBasis::GradSvd(b) => b.project_into(x, out, scratch),
            AnyBasis::TensorEigen(b) => b.project_into(x, out, scratch),
        }
    }

    fn project_back_into(&self, x: &Matrix, out: &mut Matrix, scratch: &mut Scratch) {
        match self {
            AnyBasis::Identity(b) => b.project_back_into(x, out, scratch),
            AnyBasis::Eigen(b) => b.project_back_into(x, out, scratch),
            AnyBasis::GradSvd(b) => b.project_back_into(x, out, scratch),
            AnyBasis::TensorEigen(b) => b.project_back_into(x, out, scratch),
        }
    }

    fn refresh_seconds(&self) -> f64 {
        match self {
            AnyBasis::Identity(b) => b.refresh_seconds(),
            AnyBasis::Eigen(b) => b.refresh_seconds(),
            AnyBasis::GradSvd(b) => b.refresh_seconds(),
            AnyBasis::TensorEigen(b) => b.refresh_seconds(),
        }
    }

    fn attach_async(&mut self, service: &Arc<RefreshService>) -> bool {
        match self {
            AnyBasis::Identity(b) => b.attach_async(service),
            AnyBasis::Eigen(b) => b.attach_async(service),
            AnyBasis::GradSvd(b) => b.attach_async(service),
            AnyBasis::TensorEigen(b) => b.attach_async(service),
        }
    }

    fn attach_dist(&mut self, owned: bool) -> Vec<DistBasisPort> {
        match self {
            AnyBasis::Identity(b) => b.attach_dist(owned),
            AnyBasis::Eigen(b) => b.attach_dist(owned),
            AnyBasis::GradSvd(b) => b.attach_dist(owned),
            AnyBasis::TensorEigen(b) => b.attach_dist(owned),
        }
    }

    fn dist_mid_step_sync(&self, t: u64) -> bool {
        match self {
            AnyBasis::Identity(b) => b.dist_mid_step_sync(t),
            AnyBasis::Eigen(b) => b.dist_mid_step_sync(t),
            AnyBasis::GradSvd(b) => b.dist_mid_step_sync(t),
            AnyBasis::TensorEigen(b) => b.dist_mid_step_sync(t),
        }
    }

    fn adopt_pending(&mut self) {
        match self {
            AnyBasis::Identity(b) => b.adopt_pending(),
            AnyBasis::Eigen(b) => b.adopt_pending(),
            AnyBasis::GradSvd(b) => b.adopt_pending(),
            AnyBasis::TensorEigen(b) => b.adopt_pending(),
        }
    }

    fn basis_snapshot_step(&self) -> Option<u64> {
        match self {
            AnyBasis::Identity(b) => b.basis_snapshot_step(),
            AnyBasis::Eigen(b) => b.basis_snapshot_step(),
            AnyBasis::GradSvd(b) => b.basis_snapshot_step(),
            AnyBasis::TensorEigen(b) => b.basis_snapshot_step(),
        }
    }

    fn whitening_offdiag(&self) -> Option<f64> {
        match self {
            AnyBasis::Identity(b) => b.whitening_offdiag(),
            AnyBasis::Eigen(b) => b.whitening_offdiag(),
            AnyBasis::GradSvd(b) => b.whitening_offdiag(),
            AnyBasis::TensorEigen(b) => b.whitening_offdiag(),
        }
    }

    fn state_bytes(&self) -> usize {
        match self {
            AnyBasis::Identity(b) => b.state_bytes(),
            AnyBasis::Eigen(b) => b.state_bytes(),
            AnyBasis::GradSvd(b) => b.state_bytes(),
            AnyBasis::TensorEigen(b) => b.state_bytes(),
        }
    }

    fn export(&self) -> BasisState {
        match self {
            AnyBasis::Identity(b) => b.export(),
            AnyBasis::Eigen(b) => b.export(),
            AnyBasis::GradSvd(b) => b.export(),
            AnyBasis::TensorEigen(b) => b.export(),
        }
    }

    fn import(
        &mut self,
        flags: &[f32],
        it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()> {
        match self {
            AnyBasis::Identity(b) => b.import(flags, it),
            AnyBasis::Eigen(b) => b.import(flags, it),
            AnyBasis::GradSvd(b) => b.import(flags, it),
            AnyBasis::TensorEigen(b) => b.import(flags, it),
        }
    }

    fn layout(&self) -> StateLayout {
        match self {
            AnyBasis::Identity(b) => b.layout(),
            AnyBasis::Eigen(b) => b.layout(),
            AnyBasis::GradSvd(b) => b.layout(),
            AnyBasis::TensorEigen(b) => b.layout(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eigen_dim_cap_boundary_preconditions_at_equality() {
        // The 2-D reference for the boundary convention the tensor basis
        // must agree with (see `tensor_basis::tests`): a side whose dim is
        // EXACTLY `max_precond_dim` is preconditioned; `cap + 1` keeps
        // identity. Both sides of the boundary, both sides of the matrix.
        let h = Hyper { max_precond_dim: 8, ..Hyper::default() };
        let b = EigenBasis::rotation(8, 9, &h);
        assert!(b.l.is_some(), "rows == cap must be preconditioned");
        assert!(b.r.is_none(), "cols == cap + 1 must stay identity");
        let b = EigenBasis::rotation(9, 8, &h);
        assert!(b.l.is_none() && b.r.is_some());
        let b = EigenBasis::rotation(8, 8, &h);
        assert!(b.l.is_some() && b.r.is_some());
    }
}
