//! Dtype-generic storage for slow-moving optimizer state (`--state-dtype`).
//!
//! [`StateMatrix`] / [`StateVec`] hold the Kronecker-factor EMAs and the
//! Adam/Adafactor second moments either as plain f32 (the bitwise-pinned
//! default) or as bf16 (`u16` = the top half of the f32 bit pattern),
//! halving their `state_bytes` (paper §7.2 accounting). **Accumulation is
//! always f32**: every update decodes the stored value, evaluates the exact
//! same f32 EMA expression the f32 path uses, then rounds the result back
//! to storage (round-to-nearest-even).
//!
//! # Read-back semantics
//!
//! Consumers in the same pass read the *re-decoded stored value*, not the
//! pre-rounding f32 — [`StateMatrix::ema_then`] hands its `use_v` callback
//! the value a fresh decode would produce. This keeps the fused
//! (`direction_into`) and allocating-reference (`direction`) paths bitwise
//! identical under **both** dtypes, and makes checkpoint resume exact: the
//! f32 wire tensors a bf16 buffer exports decode from the bf16 grid, so
//! re-encoding them on import reproduces the identical `u16` words.
//!
//! In the `F32` arms every expression is written to match the pre-existing
//! `Matrix` code character for character (e.g. [`StateMatrix::ema_inplace`]
//! vs `Matrix::ema_inplace`), so the default dtype stays bitwise-pinned by
//! the golden trajectory tests.

use crate::linalg::Matrix;
use crate::optim::hyper::StateDtype;

/// Decode a bf16 word: exact widening (the bf16 value set is a subset of
/// f32), so decode ∘ encode ∘ decode ≡ decode.
#[inline]
pub fn bf16_decode(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Encode an f32 to bf16 with round-to-nearest-even. NaN keeps its sign/
/// payload top bits with the quiet bit forced (truncation alone could turn
/// a signaling-NaN payload into Inf); overflow rounds to ±Inf like any IEEE
/// narrowing.
#[inline]
pub fn bf16_encode(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let bias = 0x7FFF + ((bits >> 16) & 1);
    ((bits + bias) >> 16) as u16
}

/// A `rows×cols` state buffer stored at the run's [`StateDtype`].
#[derive(Clone, Debug)]
pub enum StateMatrix {
    F32(Matrix),
    Bf16 { rows: usize, cols: usize, data: Vec<u16> },
}

impl StateMatrix {
    pub fn zeros(rows: usize, cols: usize, dtype: StateDtype) -> Self {
        match dtype {
            StateDtype::F32 => StateMatrix::F32(Matrix::zeros(rows, cols)),
            StateDtype::Bf16 => StateMatrix::Bf16 { rows, cols, data: vec![0; rows * cols] },
        }
    }

    /// Encode an f32 matrix at the requested dtype (checkpoint import, basis
    /// init).
    pub fn from_matrix(m: &Matrix, dtype: StateDtype) -> Self {
        match dtype {
            StateDtype::F32 => StateMatrix::F32(m.clone()),
            StateDtype::Bf16 => StateMatrix::Bf16 {
                rows: m.rows,
                cols: m.cols,
                data: m.data.iter().map(|&x| bf16_encode(x)).collect(),
            },
        }
    }

    pub fn dtype(&self) -> StateDtype {
        match self {
            StateMatrix::F32(_) => StateDtype::F32,
            StateMatrix::Bf16 { .. } => StateDtype::Bf16,
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            StateMatrix::F32(m) => m.rows,
            StateMatrix::Bf16 { rows, .. } => *rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            StateMatrix::F32(m) => m.cols,
            StateMatrix::Bf16 { cols, .. } => *cols,
        }
    }

    pub fn numel(&self) -> usize {
        self.rows() * self.cols()
    }

    /// Persistent bytes at the storage dtype — the §7.2 accounting number.
    pub fn state_bytes(&self) -> usize {
        self.numel() * self.dtype().bytes()
    }

    /// Decode to a fresh f32 matrix (allocating — refresh-time and
    /// reference paths only, never the steady-state step).
    pub fn to_matrix(&self) -> Matrix {
        match self {
            StateMatrix::F32(m) => m.clone(),
            StateMatrix::Bf16 { rows, cols, data } => Matrix {
                rows: *rows,
                cols: *cols,
                data: data.iter().map(|&b| bf16_decode(b)).collect(),
            },
        }
    }

    /// Overwrite from an f32 matrix, re-encoding at the storage dtype.
    /// Shape-preserving and allocation-free once sized.
    pub fn assign_from(&mut self, src: &Matrix) {
        match self {
            StateMatrix::F32(m) => {
                m.rows = src.rows;
                m.cols = src.cols;
                m.data.clear();
                m.data.extend_from_slice(&src.data);
            }
            StateMatrix::Bf16 { rows, cols, data } => {
                *rows = src.rows;
                *cols = src.cols;
                data.clear();
                data.extend(src.data.iter().map(|&x| bf16_encode(x)));
            }
        }
    }

    /// EMA into storage: `self ← beta·self + (1−beta)·other`, f32 math on
    /// the decoded value. The `F32` arm is the exact `Matrix::ema_inplace`
    /// expression.
    pub fn ema_inplace(&mut self, other: &Matrix, beta: f32) {
        let ob = 1.0 - beta;
        match self {
            StateMatrix::F32(m) => {
                for (a, &b) in m.data.iter_mut().zip(&other.data) {
                    *a = beta * *a + ob * b;
                }
            }
            StateMatrix::Bf16 { data, .. } => {
                for (a, &b) in data.iter_mut().zip(&other.data) {
                    *a = bf16_encode(beta * bf16_decode(*a) + ob * b);
                }
            }
        }
    }

    /// Fused per-element update + same-pass consumption: for each index,
    /// `ema(i, stored_i)` produces the new value, which is written to
    /// storage; `use_v(i, read_back_i)` then receives the value a fresh
    /// decode of storage yields (for f32 the two are the same number).
    /// Allocation-free — this is the steady-state moment-kernel path.
    pub fn ema_then(&mut self, mut ema: impl FnMut(usize, f32) -> f32, mut use_v: impl FnMut(usize, f32)) {
        match self {
            StateMatrix::F32(m) => {
                for (i, v) in m.data.iter_mut().enumerate() {
                    *v = ema(i, *v);
                    use_v(i, *v);
                }
            }
            StateMatrix::Bf16 { data, .. } => {
                for (i, b) in data.iter_mut().enumerate() {
                    *b = bf16_encode(ema(i, bf16_decode(*b)));
                    use_v(i, bf16_decode(*b));
                }
            }
        }
    }

    /// All stored values finite? (bf16 decodes first — Inf/NaN survive the
    /// encoding, so the health check sees them.)
    pub fn is_finite(&self) -> bool {
        match self {
            StateMatrix::F32(m) => m.data.iter().all(|x| x.is_finite()),
            StateMatrix::Bf16 { data, .. } => data.iter().all(|&b| bf16_decode(b).is_finite()),
        }
    }
}

/// A 1-D state buffer (Adafactor row/column accumulators) at the run's
/// [`StateDtype`].
#[derive(Clone, Debug)]
pub enum StateVec {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
}

impl StateVec {
    pub fn zeros(len: usize, dtype: StateDtype) -> Self {
        match dtype {
            StateDtype::F32 => StateVec::F32(vec![0.0; len]),
            StateDtype::Bf16 => StateVec::Bf16(vec![0; len]),
        }
    }

    pub fn from_slice(vals: &[f32], dtype: StateDtype) -> Self {
        match dtype {
            StateDtype::F32 => StateVec::F32(vals.to_vec()),
            StateDtype::Bf16 => StateVec::Bf16(vals.iter().map(|&x| bf16_encode(x)).collect()),
        }
    }

    pub fn dtype(&self) -> StateDtype {
        match self {
            StateVec::F32(_) => StateDtype::F32,
            StateVec::Bf16(_) => StateDtype::Bf16,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            StateVec::F32(v) => v.len(),
            StateVec::Bf16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn state_bytes(&self) -> usize {
        self.len() * self.dtype().bytes()
    }

    /// Per-element update into storage (decode → `f` → encode), matching
    /// [`StateMatrix::ema_then`] without a consumer. Allocation-free.
    pub fn ema_update(&mut self, mut f: impl FnMut(usize, f32) -> f32) {
        match self {
            StateVec::F32(v) => {
                for (i, a) in v.iter_mut().enumerate() {
                    *a = f(i, *a);
                }
            }
            StateVec::Bf16(v) => {
                for (i, b) in v.iter_mut().enumerate() {
                    *b = bf16_encode(f(i, bf16_decode(*b)));
                }
            }
        }
    }

    /// Iterate the decoded (read-back) values. Allocation-free.
    pub fn iter_decoded(&self) -> impl Iterator<Item = f32> + '_ {
        // Two arms, one iterator type: decode is the identity on f32 bits.
        let (f, b) = match self {
            StateVec::F32(v) => (Some(v.iter().copied()), None),
            StateVec::Bf16(v) => (None, Some(v.iter().map(|&x| bf16_decode(x)))),
        };
        f.into_iter().flatten().chain(b.into_iter().flatten())
    }

    /// Decoded copy (allocating — export/reference paths only).
    pub fn to_vec(&self) -> Vec<f32> {
        self.iter_decoded().collect()
    }

    /// Overwrite from f32 values, re-encoding at the storage dtype.
    pub fn assign_from(&mut self, vals: &[f32]) {
        match self {
            StateVec::F32(v) => {
                v.clear();
                v.extend_from_slice(vals);
            }
            StateVec::Bf16(v) => {
                v.clear();
                v.extend(vals.iter().map(|&x| bf16_encode(x)));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bf16_codec_exact_on_representable_values() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, -3.5, 256.0, 0.00390625, f32::INFINITY] {
            let rt = bf16_decode(bf16_encode(x));
            assert_eq!(rt.to_bits(), x.to_bits(), "{x} not preserved (got {rt})");
        }
        assert!(bf16_decode(bf16_encode(f32::NAN)).is_nan());
        assert!(bf16_decode(bf16_encode(f32::NEG_INFINITY)).is_infinite());
        // Idempotence: a decoded value re-encodes to the identical word.
        let mut rng = Rng::new(11);
        let mut xs = vec![0.0f32; 256];
        rng.fill_normal(&mut xs, 3.0);
        for x in xs {
            let w = bf16_encode(x);
            assert_eq!(bf16_encode(bf16_decode(w)), w, "encode not idempotent for {x}");
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0 = 0x3F800000; the next bf16 up is 0x3F81 (1.0078125). The
        // halfway point 0x3F808000 must round to even (0x3F80), one ULP
        // above it must round up.
        assert_eq!(bf16_encode(f32::from_bits(0x3F80_8000)), 0x3F80);
        assert_eq!(bf16_encode(f32::from_bits(0x3F80_8001)), 0x3F81);
        // Halfway above an odd word rounds up to the even neighbor.
        assert_eq!(bf16_encode(f32::from_bits(0x3F81_8000)), 0x3F82);
        assert_eq!(bf16_encode(f32::from_bits(0x3F80_7FFF)), 0x3F80);
    }

    #[test]
    fn bf16_relative_error_is_bounded() {
        // RNE to an 8-bit mantissa: relative error ≤ 2⁻⁹ for normal values.
        let mut rng = Rng::new(12);
        let mut xs = vec![0.0f32; 4096];
        rng.fill_normal(&mut xs, 10.0);
        for x in xs {
            let err = (bf16_decode(bf16_encode(x)) - x).abs();
            assert!(err <= x.abs() / 512.0 + f32::MIN_POSITIVE, "|Δ|={err} for {x}");
        }
    }

    #[test]
    fn f32_arm_matches_matrix_ema_bitwise() {
        let mut rng = Rng::new(13);
        let mut reference = Matrix::randn(&mut rng, 7, 5, 1.0);
        let mut sm = StateMatrix::from_matrix(&reference, StateDtype::F32);
        for _ in 0..10 {
            let obs = Matrix::randn(&mut rng, 7, 5, 1.0);
            reference.ema_inplace(&obs, 0.95);
            sm.ema_inplace(&obs, 0.95);
        }
        assert_eq!(sm.to_matrix().data, reference.data, "F32 arm drifted from Matrix");
    }

    #[test]
    fn bf16_factor_ema_error_bound() {
        // An EMA of random PSD-ish observations: bf16 storage must track the
        // f32 trajectory within a small relative Frobenius error — each
        // write rounds at 2⁻⁹, and the EMA keeps old rounding errors from
        // accumulating (they decay geometrically).
        let mut rng = Rng::new(14);
        let mut f32_ema = Matrix::zeros(8, 8);
        let mut bf16_ema = StateMatrix::zeros(8, 8, StateDtype::Bf16);
        for _ in 0..50 {
            let g = Matrix::randn(&mut rng, 8, 4, 1.0);
            let obs = g.matmul_nt(&g);
            f32_ema.ema_inplace(&obs, 0.95);
            bf16_ema.ema_inplace(&obs, 0.95);
        }
        let dec = bf16_ema.to_matrix();
        let num = dec.sub(&f32_ema).frob_norm();
        let den = f32_ema.frob_norm().max(1e-12);
        let rel = num / den;
        assert!(rel < 0.01, "bf16 factor EMA drifted {rel} from f32");
        assert!(rel > 0.0, "bf16 EMA suspiciously exact — encoding inert?");
    }

    #[test]
    fn ema_then_hands_consumer_the_read_back_value() {
        for dtype in [StateDtype::F32, StateDtype::Bf16] {
            let mut sm = StateMatrix::zeros(2, 3, dtype);
            let mut seen = Vec::new();
            sm.ema_then(|i, v| 0.9 * v + 0.1 * (i as f32 + 0.123), |_, v| seen.push(v));
            assert_eq!(seen, sm.to_matrix().data, "{dtype:?}: consumer saw pre-rounding value");
        }
    }

    #[test]
    fn state_bytes_halve_under_bf16() {
        let m = StateMatrix::zeros(16, 16, StateDtype::F32);
        let b = StateMatrix::zeros(16, 16, StateDtype::Bf16);
        assert_eq!(m.state_bytes(), 16 * 16 * 4);
        assert_eq!(b.state_bytes(), 16 * 16 * 2);
        let v = StateVec::zeros(10, StateDtype::F32);
        let w = StateVec::zeros(10, StateDtype::Bf16);
        assert_eq!(v.state_bytes(), 40);
        assert_eq!(w.state_bytes(), 20);
    }

    #[test]
    fn export_import_round_trip_is_exact_per_dtype() {
        let mut rng = Rng::new(15);
        for dtype in [StateDtype::F32, StateDtype::Bf16] {
            let mut sm = StateMatrix::zeros(5, 4, dtype);
            let obs = Matrix::randn(&mut rng, 5, 4, 2.0);
            sm.ema_inplace(&obs, 0.5);
            // Checkpoint wire: decode to f32, re-encode on import.
            let wire = sm.to_matrix();
            let back = StateMatrix::from_matrix(&wire, dtype);
            match (&sm, &back) {
                (StateMatrix::F32(a), StateMatrix::F32(b)) => assert_eq!(a.data, b.data),
                (StateMatrix::Bf16 { data: a, .. }, StateMatrix::Bf16 { data: b, .. }) => {
                    assert_eq!(a, b, "bf16 words changed across the f32 wire")
                }
                _ => panic!("dtype changed in round trip"),
            }
        }
    }

    #[test]
    fn nonfinite_values_survive_encoding_for_health_checks() {
        let mut src = Matrix::zeros(2, 2);
        src.data[3] = f32::NAN;
        let sm = StateMatrix::from_matrix(&src, StateDtype::Bf16);
        assert!(!sm.is_finite(), "NaN lost in bf16 encode");
        let mut src = Matrix::zeros(2, 2);
        src.data[0] = f32::INFINITY;
        assert!(!StateMatrix::from_matrix(&src, StateDtype::Bf16).is_finite());
        assert!(StateMatrix::zeros(3, 3, StateDtype::Bf16).is_finite());
    }

    #[test]
    fn state_vec_update_and_iter_round_trip() {
        for dtype in [StateDtype::F32, StateDtype::Bf16] {
            let mut v = StateVec::zeros(4, dtype);
            v.ema_update(|i, a| 0.9 * a + 0.1 * (i as f32 + 1.5));
            let vals: Vec<f32> = v.iter_decoded().collect();
            assert_eq!(vals.len(), 4);
            assert_eq!(vals, v.to_vec());
            // assign_from re-encodes exactly (values already on the grid).
            let mut w = StateVec::zeros(4, dtype);
            w.assign_from(&vals);
            assert_eq!(w.to_vec(), vals, "{dtype:?} wire round trip drifted");
        }
    }
}
