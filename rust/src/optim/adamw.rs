//! AdamW (Kingma & Ba 2015; decoupled weight decay) — the paper's primary
//! baseline, as the trivial point of the composable core:
//!
//! ```text
//!   AdamW = IdentityBasis × Adam
//! ```
//!
//! The same [`crate::optim::compose::AdamEngine`] is the inner rule of SOAP (rotated into
//! the eigenbasis) and GaLore (in the gradient-SVD projection) — the paper's
//! "Adam is the fixed point of the family" observation. Matches the standard
//! PyTorch semantics: bias-corrected moments, `m̂ / (√v̂ + ε)`, decoupled
//! weight decay.

use super::compose::{presets, DynComposed};
use super::hyper::Hyper;
use crate::linalg::Matrix;

/// Named preset: [`AdamW::new`] builds the identity × Adam composition.
/// Also hosts [`AdamW::direction`], the raw update formula shared with the
/// grafting wrapper.
pub struct AdamW;

impl AdamW {
    // Historical constructor name, kept across the compose refactor; it
    // intentionally returns the composed type, not Self.
    #[allow(clippy::new_ret_no_self)]
    pub fn new(rows: usize, cols: usize, h: Hyper) -> DynComposed {
        presets::adamw(rows, cols, h)
    }

    /// The raw AdamW direction `m̂/(√v̂+ε)` for the given moments — exposed so
    /// [`crate::optim::compose::Graft`](super::compose::Graft) can reuse it.
    pub fn direction(m: &Matrix, v: &Matrix, t: u64, beta1: f32, beta2: f32, eps: f32) -> Matrix {
        let bc1 = 1.0 - beta1.powi(t as i32);
        let bc2 = 1.0 - beta2.powi(t as i32);
        m.zip(v, |mi, vi| (mi / bc1) / ((vi / bc2).max(0.0).sqrt() + eps))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::LayerOptimizer;
    use crate::util::rng::Rng;

    fn h_nowd() -> Hyper {
        Hyper { weight_decay: 0.0, ..Hyper::default() }
    }

    #[test]
    fn first_step_is_sign_sgd_like() {
        // With bias correction, step 1 direction ≈ g/|g| elementwise.
        let mut opt = AdamW::new(1, 3, h_nowd());
        let mut w = Matrix::zeros(1, 3);
        let g = Matrix::from_vec(1, 3, vec![0.5, -2.0, 1e-12]);
        opt.update(&mut w, &g, 1, 0.1);
        assert!((w.data[0] + 0.1).abs() < 1e-3);
        assert!((w.data[1] - 0.1).abs() < 1e-3);
        assert!(w.data[2].abs() < 0.1); // ε-dominated
    }

    #[test]
    fn constant_gradient_converges_to_unit_direction() {
        let mut opt = AdamW::new(1, 2, h_nowd());
        let mut w = Matrix::zeros(1, 2);
        let g = Matrix::from_vec(1, 2, vec![3.0, -0.2]);
        let mut last = w.clone();
        for t in 1..=200 {
            last = w.clone();
            opt.update(&mut w, &g, t, 0.01);
        }
        let step0 = last.data[0] - w.data[0];
        let step1 = last.data[1] - w.data[1];
        // Both coordinates step ~lr in magnitude regardless of grad scale.
        assert!((step0 - 0.01).abs() < 1e-3, "{step0}");
        assert!((step1 + 0.01).abs() < 1e-3, "{step1}");
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let h = Hyper { weight_decay: 0.1, ..Hyper::default() };
        let mut opt = AdamW::new(1, 1, h);
        let mut w = Matrix::from_vec(1, 1, vec![1.0]);
        let g = Matrix::zeros(1, 1);
        opt.update(&mut w, &g, 1, 0.5);
        // No gradient signal: pure decay 1·(1−0.5·0.1).
        assert!((w.data[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn minimizes_quadratic() {
        // f(w) = ||w − w*||², gradient 2(w−w*).
        let mut rng = Rng::new(5);
        let target = Matrix::randn(&mut rng, 4, 4, 1.0);
        let mut w = Matrix::zeros(4, 4);
        let mut opt = AdamW::new(4, 4, h_nowd());
        for t in 1..=2000 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.05);
        }
        assert!(w.max_abs_diff(&target) < 0.05);
    }

    #[test]
    fn state_bytes_is_2mn() {
        let opt = AdamW::new(8, 16, Hyper::default());
        assert_eq!(opt.state_bytes(), 2 * 8 * 16 * 4);
    }

    #[test]
    fn bf16_state_halves_v_but_not_m() {
        use crate::optim::hyper::StateDtype;
        let h = Hyper { state_dtype: StateDtype::Bf16, ..Hyper::default() };
        let opt = AdamW::new(8, 16, h);
        // M stays f32 (4 bytes); V stores bf16 (2 bytes).
        assert_eq!(opt.state_bytes(), 8 * 16 * 4 + 8 * 16 * 2);
    }
}
