//! Learning-rate schedules — warmup + cosine decay, matching Appendix A:
//! warmup starts at 0.1× max LR and the cosine decays back to 0.1× max LR.

#[derive(Clone, Copy, Debug)]
pub enum Schedule {
    Constant { lr: f32 },
    /// Linear warmup from `floor_frac·lr` over `warmup` steps, then cosine
    /// decay to `floor_frac·lr` at `total` steps.
    WarmupCosine { lr: f32, warmup: u64, total: u64, floor_frac: f32 },
}

impl Schedule {
    /// Paper-default schedule: floor fraction 0.1.
    pub fn paper(lr: f32, warmup: u64, total: u64) -> Self {
        Schedule::WarmupCosine { lr, warmup, total, floor_frac: 0.1 }
    }

    /// LR at (0-based) step `t`.
    pub fn lr_at(&self, t: u64) -> f32 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::WarmupCosine { lr, warmup, total, floor_frac } => {
                let floor = floor_frac * lr;
                if warmup > 0 && t < warmup {
                    let p = t as f32 / warmup as f32;
                    floor + (lr - floor) * p
                } else if t >= total {
                    floor
                } else {
                    let span = (total - warmup).max(1) as f32;
                    let p = (t - warmup) as f32 / span;
                    let cos = 0.5 * (1.0 + (std::f32::consts::PI * p).cos());
                    floor + (lr - floor) * cos
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_starts_at_floor_and_peaks() {
        let s = Schedule::paper(1.0, 100, 1000);
        assert!((s.lr_at(0) - 0.1).abs() < 1e-6);
        assert!((s.lr_at(100) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn decays_to_floor() {
        let s = Schedule::paper(1.0, 100, 1000);
        assert!((s.lr_at(1000) - 0.1).abs() < 1e-5);
        assert!((s.lr_at(5000) - 0.1).abs() < 1e-5);
    }

    #[test]
    fn monotone_up_then_down() {
        let s = Schedule::paper(0.01, 50, 500);
        for t in 0..49 {
            assert!(s.lr_at(t) <= s.lr_at(t + 1) + 1e-9);
        }
        for t in 50..499 {
            assert!(s.lr_at(t) >= s.lr_at(t + 1) - 1e-9);
        }
    }

    #[test]
    fn midpoint_is_mean_of_peak_and_floor() {
        let s = Schedule::paper(1.0, 0, 1000);
        let mid = s.lr_at(500);
        assert!((mid - 0.55).abs() < 1e-3); // 0.1 + 0.9·0.5
    }

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant { lr: 0.3 };
        assert_eq!(s.lr_at(0), 0.3);
        assert_eq!(s.lr_at(123456), 0.3);
    }
}
