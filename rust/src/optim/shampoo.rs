//! Shampoo (Gupta et al. 2018), in the DistributedShampoo (Shi et al. 2023)
//! configuration the paper benchmarks against: EMA Kronecker factors
//! `L ← β_s L + (1−β_s) GGᵀ`, `R ← β_s R + (1−β_s) GᵀG`, inverse roots
//! `L^{-1/e}, R^{-1/e}` recomputed every `f` steps (preconditioning
//! frequency), layerwise AdamW **grafting**, and momentum applied in the
//! original space.
//!
//! The paper's key criticism — that Shampoo's second-moment "adaptivity" is
//! frozen between refreshes (only the scalar grafting norm adapts per step)
//! — falls straight out of this structure: the direction uses the stale
//! `L^{-1/e}` factors, while SOAP (see `soap.rs`) refreshes its diagonal
//! second moment every step.

use std::sync::Arc;
use std::time::Instant;

use super::adamw::AdamW;
use super::hyper::Hyper;
use super::LayerOptimizer;
use crate::linalg::{eigh, eigh_warm, roots::inv_root_from_eig, Matrix};
use crate::precond::{BasisHandle, BasisPayload, RefreshService};

pub struct Shampoo {
    h: Hyper,
    /// Momentum (original space).
    m: Matrix,
    /// Kronecker factors (EMAs).
    l: Matrix,
    r: Matrix,
    /// Cached inverse roots, recomputed every `f` steps.
    l_inv: Matrix,
    r_inv: Matrix,
    /// AdamW second moment for grafting.
    v_graft: Matrix,
    /// Cached eigenbases for warm-started refreshes (§Perf: the periodic
    /// root recompute reuses the previous basis, dropping cold Jacobi cost
    /// to a few GEMMs + ~1 sweep — the paper's refreshes change L/R slowly).
    l_vecs: Option<Matrix>,
    r_vecs: Option<Matrix>,
    initialized: bool,
    refresh_secs: f64,
    /// Async refresh plumbing (`None` ⇒ inline root recomputes). Grafting
    /// keeps the scalar step size adapting every step while the roots age —
    /// the same argument that makes SOAP tolerate a stale basis.
    service: Option<Arc<RefreshService>>,
    handle: Option<Arc<BasisHandle>>,
    adopted_version: u64,
    /// Step whose factors back the ACTIVE inverse roots.
    basis_step: u64,
}

impl Shampoo {
    pub fn new(rows: usize, cols: usize, h: Hyper) -> Self {
        Self {
            h,
            m: Matrix::zeros(rows, cols),
            l: Matrix::zeros(rows, rows),
            r: Matrix::zeros(cols, cols),
            l_inv: Matrix::eye(rows),
            r_inv: Matrix::eye(cols),
            v_graft: Matrix::zeros(rows, cols),
            l_vecs: None,
            r_vecs: None,
            initialized: false,
            refresh_secs: 0.0,
            service: None,
            handle: None,
            adopted_version: 0,
            basis_step: 0,
        }
    }

    /// The root-recompute math as a pure function of bias-corrected factor
    /// snapshots, shared verbatim by the inline and background paths.
    /// Returns `(l_inv, r_inv, l_vecs, r_vecs)`.
    fn compute_roots(
        lh: &Matrix,
        rh: &Matrix,
        prev_l: Option<&Matrix>,
        prev_r: Option<&Matrix>,
        e: f32,
        eps: f32,
    ) -> (Matrix, Matrix, Matrix, Matrix) {
        let (wl, vl) = match prev_l {
            Some(prev) => eigh_warm(lh, prev),
            None => eigh(lh),
        };
        let (wr, vr) = match prev_r {
            Some(prev) => eigh_warm(rh, prev),
            None => eigh(rh),
        };
        let l_inv = inv_root_from_eig(&wl, &vl, e, eps);
        let r_inv = inv_root_from_eig(&wr, &vr, e, eps);
        (l_inv, r_inv, vl, vr)
    }

    /// Bias-corrected factor snapshots at step `t`.
    fn corrected_factors(&self, t: u64) -> (Matrix, Matrix) {
        let bc = 1.0 - self.h.shampoo_beta.powi(t as i32);
        (self.l.scale(1.0 / bc), self.r.scale(1.0 / bc))
    }

    fn refresh_roots(&mut self, t: u64) {
        let t0 = Instant::now();
        // Per-factor exponent −1/e: the update is L^{-1/e} G R^{-1/e}.
        // e = 4 is original Shampoo, e = 2 the Anil et al / Morwani et al
        // power-1/2 variant, e = 2.5 the paper's DistributedShampoo default
        // (Appendix A: "we set the default values of exponent to be −1/2.5").
        let (lh, rh) = self.corrected_factors(t);
        let (l_inv, r_inv, vl, vr) = Self::compute_roots(
            &lh,
            &rh,
            self.l_vecs.as_ref(),
            self.r_vecs.as_ref(),
            self.h.shampoo_exponent,
            self.h.shampoo_eps,
        );
        self.l_inv = l_inv;
        self.r_inv = r_inv;
        self.l_vecs = Some(vl);
        self.r_vecs = Some(vr);
        self.basis_step = t;
        self.refresh_secs += t0.elapsed().as_secs_f64();
    }

    /// Async mode: adopt the newest published inverse roots, if any.
    fn adopt_published(&mut self) {
        let Some(handle) = &self.handle else { return };
        if handle.version() <= self.adopted_version {
            return;
        }
        if let Some(published) = handle.latest() {
            if published.version > self.adopted_version {
                let p = &published.payload;
                if let (Some(li), Some(ri)) = (&p.left, &p.right) {
                    self.l_inv = li.clone();
                    self.r_inv = ri.clone();
                }
                self.l_vecs = p.left_aux.clone().or_else(|| self.l_vecs.take());
                self.r_vecs = p.right_aux.clone().or_else(|| self.r_vecs.take());
                self.adopted_version = published.version;
                self.basis_step = published.snapshot_step;
            }
        }
    }

    /// Async mode: snapshot bias-corrected factors + warm-start bases and
    /// hand the inverse-root recompute to the service.
    fn enqueue_refresh(&self, service: &Arc<RefreshService>, handle: &Arc<BasisHandle>, t: u64) {
        if !handle.try_begin_refresh() {
            return;
        }
        let (lh, rh) = self.corrected_factors(t);
        let prev_l = self.l_vecs.clone();
        let prev_r = self.r_vecs.clone();
        let e = self.h.shampoo_exponent;
        let eps = self.h.shampoo_eps;
        service.enqueue(
            Arc::clone(handle),
            t,
            Box::new(move || {
                let (l_inv, r_inv, vl, vr) =
                    Self::compute_roots(&lh, &rh, prev_l.as_ref(), prev_r.as_ref(), e, eps);
                BasisPayload {
                    left: Some(l_inv),
                    right: Some(r_inv),
                    left_aux: Some(vl),
                    right_aux: Some(vr),
                }
            }),
        );
    }
}

impl LayerOptimizer for Shampoo {
    fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
        let h = self.h.clone();

        // --- factor updates --------------------------------------------------
        let ggt = g.matmul_nt(g);
        let gtg = g.matmul_tn(g);
        self.l.ema_inplace(&ggt, h.shampoo_beta);
        self.r.ema_inplace(&gtg, h.shampoo_beta);

        // --- refresh inverse roots at frequency f (and on first step) -------
        // Async mode: adopt whatever the background service has published,
        // then (at this layer's phase) snapshot and re-enqueue. The first
        // recompute always runs inline so the roots are never identity-only.
        self.adopt_published();
        if !self.initialized {
            self.refresh_roots(t);
            self.initialized = true;
        } else if h.is_refresh_step(t) {
            match (self.service.clone(), self.handle.clone()) {
                (Some(service), Some(handle)) => self.enqueue_refresh(&service, &handle, t),
                _ => self.refresh_roots(t),
            }
        }

        // --- momentum + preconditioned direction -----------------------------
        self.m.ema_inplace(g, h.beta1);
        let bc1 = 1.0 - h.beta1.powi(t as i32);
        let m_hat = self.m.scale(1.0 / bc1);
        let mut dir = self.l_inv.matmul(&m_hat).matmul(&self.r_inv);

        // --- layerwise AdamW grafting ----------------------------------------
        if h.grafting {
            let g2 = g.hadamard(g);
            self.v_graft.ema_inplace(&g2, h.beta2);
            let adam_dir =
                AdamW::direction(&self.m, &self.v_graft, t, h.beta1, h.beta2, h.eps);
            let target = adam_dir.frob_norm();
            let actual = dir.frob_norm();
            if actual > 1e-30 {
                dir.scale_inplace(target / actual);
            }
        }

        w.axpy_inplace(-lr, &dir);
        if h.weight_decay != 0.0 {
            w.scale_inplace(1.0 - lr * h.weight_decay);
        }
    }

    fn state_bytes(&self) -> usize {
        // L, R, L_inv, R_inv (2m²+2n²) + M, V_graft (2mn) — matches the
        // paper §7.2 DistributedShampoo accounting (their "Q_L,Q_R" slots are
        // our cached inverse roots).
        (self.l.numel() + self.r.numel() + self.l_inv.numel() + self.r_inv.numel()
            + self.m.numel()
            + self.v_graft.numel())
            * 4
    }

    fn name(&self) -> &'static str {
        "shampoo"
    }

    fn refresh_seconds(&self) -> f64 {
        self.refresh_secs
    }

    fn attach_async(&mut self, service: &Arc<RefreshService>) -> bool {
        self.service = Some(Arc::clone(service));
        self.handle = Some(Arc::new(BasisHandle::new()));
        self.adopted_version = 0;
        true
    }

    fn basis_snapshot_step(&self) -> Option<u64> {
        self.initialized.then_some(self.basis_step)
    }

    fn export_state(&self) -> Vec<Matrix> {
        // flags[1] = basis_step, so staleness survives a checkpoint resume.
        let flags = Matrix::from_vec(
            1,
            2,
            vec![self.initialized as u8 as f32, self.basis_step as f32],
        );
        vec![
            flags,
            self.m.clone(),
            self.l.clone(),
            self.r.clone(),
            self.l_inv.clone(),
            self.r_inv.clone(),
            self.v_graft.clone(),
        ]
    }

    fn import_state(&mut self, state: Vec<Matrix>) -> anyhow::Result<()> {
        anyhow::ensure!(state.len() == 7, "shampoo expects 7 state tensors");
        let mut it = state.into_iter();
        let flags = it.next().unwrap();
        // cols == 1 accepts pre-basis_step checkpoints.
        anyhow::ensure!(flags.cols == 1 || flags.cols == 2, "shampoo state flags malformed");
        self.initialized = flags.data[0] != 0.0;
        self.basis_step = if flags.cols == 2 { flags.data[1] as u64 } else { 0 };
        // Refreshes enqueued before the restore were computed from discarded
        // factors; drain them, then skip every pre-restore publication.
        if let (Some(service), Some(handle)) = (&self.service, &self.handle) {
            service.wait_idle();
            self.adopted_version = handle.version();
        }
        self.m = it.next().unwrap();
        self.l = it.next().unwrap();
        self.r = it.next().unwrap();
        self.l_inv = it.next().unwrap();
        self.r_inv = it.next().unwrap();
        self.v_graft = it.next().unwrap();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn h_base() -> Hyper {
        Hyper { weight_decay: 0.0, precond_freq: 1, ..Hyper::default() }
    }

    #[test]
    fn minimizes_quadratic() {
        let mut rng = Rng::new(7);
        let target = Matrix::randn(&mut rng, 6, 4, 1.0);
        let mut w = Matrix::zeros(6, 4);
        let mut opt = Shampoo::new(6, 4, h_base());
        for t in 1..=1500 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.1, "{}", w.max_abs_diff(&target));
    }

    #[test]
    fn grafting_matches_adam_norm() {
        // With grafting, the applied direction norm equals AdamW's direction
        // norm for the same gradient stream.
        let mut rng = Rng::new(8);
        let g = Matrix::randn(&mut rng, 5, 5, 1.0);
        let h = h_base();
        let mut sh = Shampoo::new(5, 5, h.clone());
        let mut ad = AdamW::new(5, 5, h.clone());
        let mut w_s = Matrix::zeros(5, 5);
        let mut w_a = Matrix::zeros(5, 5);
        sh.update(&mut w_s, &g, 1, 1.0);
        ad.update(&mut w_a, &g, 1, 1.0);
        let ns = w_s.frob_norm();
        let na = w_a.frob_norm();
        assert!((ns - na).abs() / na < 0.02, "shampoo {ns} vs adam {na}");
    }

    #[test]
    fn stale_roots_between_refreshes() {
        // With f = 10, the cached inverse roots must not change on
        // non-refresh steps.
        let mut rng = Rng::new(9);
        let h = Hyper { precond_freq: 10, weight_decay: 0.0, ..Hyper::default() };
        let mut opt = Shampoo::new(4, 4, h);
        let mut w = Matrix::zeros(4, 4);
        let g = Matrix::randn(&mut rng, 4, 4, 1.0);
        opt.update(&mut w, &g, 1, 0.01); // initializes roots
        let l_after_1 = opt.l_inv.clone();
        for t in 2..=9 {
            let g = Matrix::randn(&mut rng, 4, 4, 1.0);
            opt.update(&mut w, &g, t, 0.01);
        }
        assert_eq!(opt.l_inv, l_after_1, "roots changed between refreshes");
        let g = Matrix::randn(&mut rng, 4, 4, 1.0);
        opt.update(&mut w, &g, 10, 0.01);
        assert!(opt.l_inv.max_abs_diff(&l_after_1) > 0.0, "roots must refresh at f");
    }

    #[test]
    fn handles_1d_as_1xn() {
        let mut opt = Shampoo::new(1, 16, h_base());
        let mut rng = Rng::new(10);
        let mut w = Matrix::zeros(1, 16);
        for t in 1..=5 {
            let g = Matrix::randn(&mut rng, 1, 16, 1.0);
            opt.update(&mut w, &g, t, 0.01);
        }
        assert!(w.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn state_bytes_matches_paper_formula() {
        let opt = Shampoo::new(8, 4, Hyper::default());
        // 2m² + 2n² + 2mn floats.
        assert_eq!(opt.state_bytes(), (2 * 64 + 2 * 16 + 2 * 32) * 4);
    }

    #[test]
    fn async_roots_adopt_and_still_minimize() {
        let svc = Arc::new(RefreshService::new(1));
        let mut rng = Rng::new(12);
        let target = Matrix::randn(&mut rng, 6, 4, 1.0);
        let h = Hyper { weight_decay: 0.0, precond_freq: 5, ..Hyper::default() };
        let mut opt = Shampoo::new(6, 4, h);
        assert!(opt.attach_async(&svc));
        let mut w = Matrix::zeros(6, 4);
        for t in 1..=1500 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
            svc.wait_idle();
        }
        assert!(opt.adopted_version > 0, "no background root recompute adopted");
        // The t=1500 snapshot published but was never adopted (the run
        // ended); the active roots are backed by the t=1495 snapshot.
        assert_eq!(opt.basis_snapshot_step(), Some(1495));
        assert!(
            w.max_abs_diff(&target) < 0.12,
            "async Shampoo failed to converge: {}",
            w.max_abs_diff(&target)
        );
    }

    #[test]
    fn refresh_seconds_accumulates() {
        let mut opt = Shampoo::new(16, 16, h_base());
        let mut rng = Rng::new(11);
        let mut w = Matrix::zeros(16, 16);
        let g = Matrix::randn(&mut rng, 16, 16, 1.0);
        opt.update(&mut w, &g, 1, 0.01);
        assert!(opt.refresh_seconds() > 0.0);
    }
}
