//! Shampoo (Gupta et al. 2018), in the DistributedShampoo (Shi et al. 2023)
//! configuration the paper benchmarks against, as a named preset over the
//! composable core:
//!
//! ```text
//!   Shampoo = Graft( EigenBasis(inverse-root) × InverseRoot )
//! ```
//!
//! The basis ([`crate::optim::compose::EigenBasis`], inverse-root flavor) owns the EMA
//! Kronecker factors `L ← β_s L + (1−β_s) GGᵀ`, `R ← β_s R + (1−β_s) GᵀG`
//! and the cached roots `L^{-1/e}, R^{-1/e}` recomputed every `f` steps
//! (warm-started `eigh`, inline or async); the engine
//! ([`crate::optim::compose::InverseRootEngine`]) applies them to the bias-corrected
//! momentum; the [`crate::optim::compose::Graft`] wrapper rescales to AdamW's layerwise
//! norm.
//!
//! The paper's key criticism — that Shampoo's second-moment "adaptivity" is
//! frozen between refreshes (only the scalar grafting norm adapts per step)
//! — falls straight out of this composition: swap the engine for Adam and
//! the staleness problem disappears (that swap IS SOAP, see `soap.rs`).
//!
//! The composition is bitwise-identical to the pre-refactor monolithic
//! implementation (`rust/tests/golden_compose.rs`).

use super::compose::{presets, DynComposed};
use super::hyper::Hyper;

/// Named preset: [`Shampoo::new`] builds
/// `Graft(inverse-root eigenbasis × Kronecker sandwich)`. The graft state is
/// always carried (matching DistributedShampoo checkpoints); `h.grafting`
/// controls whether it is applied.
pub struct Shampoo;

impl Shampoo {
    // Historical constructor name, kept across the compose refactor; it
    // intentionally returns the composed type, not Self.
    #[allow(clippy::new_ret_no_self)]
    pub fn new(rows: usize, cols: usize, h: Hyper) -> DynComposed {
        presets::shampoo(rows, cols, h)
    }
}

pub use super::compose::EigenFlavor;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::optim::adamw::AdamW;
    use crate::optim::compose::EigenBasis;
    use crate::optim::LayerOptimizer;
    use crate::precond::RefreshService;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn h_base() -> Hyper {
        Hyper { weight_decay: 0.0, precond_freq: 1, ..Hyper::default() }
    }

    fn eigen(opt: &DynComposed) -> &EigenBasis {
        opt.basis.as_eigen().expect("shampoo preset uses the eigenbasis")
    }

    #[test]
    fn minimizes_quadratic() {
        let mut rng = Rng::new(7);
        let target = Matrix::randn(&mut rng, 6, 4, 1.0);
        let mut w = Matrix::zeros(6, 4);
        let mut opt = Shampoo::new(6, 4, h_base());
        for t in 1..=1500 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.1, "{}", w.max_abs_diff(&target));
    }

    #[test]
    fn grafting_matches_adam_norm() {
        // With grafting, the applied direction norm equals AdamW's direction
        // norm for the same gradient stream.
        let mut rng = Rng::new(8);
        let g = Matrix::randn(&mut rng, 5, 5, 1.0);
        let h = h_base();
        let mut sh = Shampoo::new(5, 5, h.clone());
        let mut ad = AdamW::new(5, 5, h.clone());
        let mut w_s = Matrix::zeros(5, 5);
        let mut w_a = Matrix::zeros(5, 5);
        sh.update(&mut w_s, &g, 1, 1.0);
        ad.update(&mut w_a, &g, 1, 1.0);
        let ns = w_s.frob_norm();
        let na = w_a.frob_norm();
        assert!((ns - na).abs() / na < 0.02, "shampoo {ns} vs adam {na}");
    }

    #[test]
    fn stale_roots_between_refreshes() {
        // With f = 10, the cached inverse roots must not change on
        // non-refresh steps.
        let mut rng = Rng::new(9);
        let h = Hyper { precond_freq: 10, weight_decay: 0.0, ..Hyper::default() };
        let mut opt = Shampoo::new(4, 4, h);
        let mut w = Matrix::zeros(4, 4);
        let g = Matrix::randn(&mut rng, 4, 4, 1.0);
        opt.update(&mut w, &g, 1, 0.01); // initializes roots
        let l_after_1 = eigen(&opt).left_q.clone().unwrap();
        for t in 2..=9 {
            let g = Matrix::randn(&mut rng, 4, 4, 1.0);
            opt.update(&mut w, &g, t, 0.01);
        }
        assert_eq!(
            eigen(&opt).left_q.as_ref().unwrap(),
            &l_after_1,
            "roots changed between refreshes"
        );
        let g = Matrix::randn(&mut rng, 4, 4, 1.0);
        opt.update(&mut w, &g, 10, 0.01);
        assert!(
            eigen(&opt).left_q.as_ref().unwrap().max_abs_diff(&l_after_1) > 0.0,
            "roots must refresh at f"
        );
    }

    #[test]
    fn handles_1d_as_1xn() {
        let mut opt = Shampoo::new(1, 16, h_base());
        let mut rng = Rng::new(10);
        let mut w = Matrix::zeros(1, 16);
        for t in 1..=5 {
            let g = Matrix::randn(&mut rng, 1, 16, 1.0);
            opt.update(&mut w, &g, t, 0.01);
        }
        assert!(w.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn state_bytes_matches_paper_formula() {
        let opt = Shampoo::new(8, 4, Hyper::default());
        // Pre-init: L, R, L_inv, R_inv (2m²+2n²) + M, V_graft (2mn).
        assert_eq!(opt.state_bytes(), (2 * 64 + 2 * 16 + 2 * 32) * 4);
        // After the first refresh the warm-start eigenvector caches exist
        // and are honestly accounted (the pre-refactor code omitted them):
        // + m² + n².
        let mut opt = opt;
        let mut rng = Rng::new(11);
        let mut w = Matrix::zeros(8, 4);
        let g = Matrix::randn(&mut rng, 8, 4, 1.0);
        opt.update(&mut w, &g, 1, 0.01);
        assert_eq!(opt.state_bytes(), (3 * 64 + 3 * 16 + 2 * 32) * 4);
    }

    #[test]
    fn async_roots_adopt_and_still_minimize() {
        let svc = Arc::new(RefreshService::new(1));
        let mut rng = Rng::new(12);
        let target = Matrix::randn(&mut rng, 6, 4, 1.0);
        let h = Hyper { weight_decay: 0.0, precond_freq: 5, ..Hyper::default() };
        let mut opt = Shampoo::new(6, 4, h);
        assert!(opt.attach_async(&svc));
        let mut w = Matrix::zeros(6, 4);
        for t in 1..=1500 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
            svc.wait_idle();
        }
        assert!(eigen(&opt).adopted_version > 0, "no background root recompute adopted");
        // The t=1500 snapshot published but was never adopted (the run
        // ended); the active roots are backed by the t=1495 snapshot.
        assert_eq!(opt.basis_snapshot_step(), Some(1495));
        assert!(
            w.max_abs_diff(&target) < 0.12,
            "async Shampoo failed to converge: {}",
            w.max_abs_diff(&target)
        );
    }

    #[test]
    fn refresh_seconds_accumulates() {
        let mut opt = Shampoo::new(16, 16, h_base());
        let mut rng = Rng::new(11);
        let mut w = Matrix::zeros(16, 16);
        let g = Matrix::randn(&mut rng, 16, 16, 1.0);
        opt.update(&mut w, &g, 1, 0.01);
        assert!(opt.refresh_seconds() > 0.0);
    }
}
