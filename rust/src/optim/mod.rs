//! Optimizers — the paper's contribution (SOAP) and every baseline it
//! evaluates against, built on a composable core that mirrors the paper's
//! structural claim: **an optimizer is a basis × an update rule (± norm
//! grafting)**.
//!
//! The [`compose`] subsystem provides the three axes:
//!
//! - [`compose::Basis`] — how the gradient is carried into a working space
//!   and back: identity, the slowly-refreshed Kronecker eigenbasis
//!   (rotation-flavored for SOAP, inverse-root-flavored for Shampoo;
//!   one/two-sided, dim-capped, QR-power-iteration or warm-`eigh`, inline or
//!   async through [`crate::precond::RefreshService`]), or GaLore's
//!   current-gradient SVD projector.
//! - [`compose::MomentEngine`] — the update rule inside that space: diagonal
//!   Adam, rank-1 Adafactor, or Shampoo's `L^{-1/e}·M̂·R^{-1/e}` sandwich.
//! - [`compose::Graft`] — optional layerwise AdamW norm grafting.
//!
//! The historical names are presets over that core — SOAP =
//! eigenbasis × Adam, factorized SOAP = eigenbasis × Adafactor, Shampoo =
//! Graft(eigenbasis × inverse-root), GaLore = grad-SVD × Adam, AdamW/
//! Adafactor = identity × {Adam, Adafactor} — and the CLI's `--optimizer`
//! accepts both the preset names and the full grammar
//! (`basis=…,inner=…[,graft=…]`, see [`compose::spec`]). Composed presets
//! reproduce the pre-refactor monolithic optimizers bitwise
//! (`rust/tests/golden_compose.rs`).
//!
//! All optimizers implement [`LayerOptimizer`] over a single parameter
//! matrix (1-D parameters are `1×n`), so the coordinator can shard layers
//! across workers. [`ModelOptimizer`] groups per-layer states under a shared
//! schedule, mirroring a framework optimizer object.
//!
//! A mirrored implementation lives in the HLO artifacts
//! (`python/compile/optim_graphs.py`); integration tests assert the two
//! trajectories agree step-for-step.

pub mod adafactor;
pub mod adamw;
pub mod compose;
pub mod galore;
pub mod hyper;
pub mod idealized;
pub mod schedule;
pub mod shampoo;
pub mod soap;

pub use adafactor::Adafactor;
pub use adamw::AdamW;
pub use compose::{Basis, Composed, CompositionSpec, DynComposed, Graft, MomentEngine};
pub use galore::Galore;
pub use hyper::{FreqSchedule, GuardPolicy, Hyper, RefreshMethod, RefreshMode, StateDtype};
pub use schedule::Schedule;
pub use shampoo::Shampoo;
pub use soap::Soap;

use std::sync::Arc;

use crate::linalg::{Matrix, TensorShape};
use crate::precond::RefreshService;

/// Per-layer optimizer state machine.
///
/// `t` is the 1-based global step (used for bias correction and the
/// preconditioning-frequency schedule).
pub trait LayerOptimizer: Send {
    /// Apply one update in place: `w ← w − lr·direction − lr·wd·w`.
    fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32);

    /// Bytes of optimizer state held for this layer (paper §7.2 accounting).
    fn state_bytes(&self) -> usize;

    /// Bytes of reusable scratch currently held for this layer (the
    /// zero-allocation step path's workspace arena — grow-only, transient).
    /// Reported separately from [`Self::state_bytes`] so the §7.2 table
    /// stays persistent-state-only while total memory stays visible.
    fn scratch_bytes(&self) -> usize {
        0
    }

    /// Human name, e.g. `"soap"`.
    fn name(&self) -> &'static str;

    /// Wall-clock spent in eigenbasis/inverse-root refreshes so far — lets
    /// the coordinator report the Fig 7 overhead split without timing hooks.
    fn refresh_seconds(&self) -> f64 {
        0.0
    }

    /// Serialize optimizer state (checkpointing). The returned matrices are
    /// opaque; `import_state` must receive them in the same order.
    fn export_state(&self) -> Vec<Matrix> {
        Vec::new()
    }

    /// Restore state produced by `export_state`.
    fn import_state(&mut self, state: Vec<Matrix>) -> anyhow::Result<()> {
        anyhow::ensure!(state.is_empty(), "optimizer expects no state");
        Ok(())
    }

    /// Route this layer's periodic preconditioner recomputes through the
    /// background refresh service instead of running them inline. Returns
    /// `false` (the default) for optimizers with nothing to refresh — the
    /// coordinator uses that to decide whether a service is needed at all.
    fn attach_async(&mut self, service: &Arc<RefreshService>) -> bool {
        let _ = service;
        false
    }

    /// Place this layer's preconditioner refreshes under distributed
    /// ownership: `owned` says whether THIS rank runs them (publishing each
    /// result for broadcast) or adopts a peer's broadcasts instead. Returns
    /// one [`crate::precond::DistBasisPort`] per refreshable component, in a
    /// deterministic order shared by every rank — empty (the default) for
    /// optimizers with nothing to broadcast, which keep refreshing locally.
    fn attach_dist(&mut self, owned: bool) -> Vec<crate::precond::DistBasisPort> {
        let _ = owned;
        Vec::new()
    }

    /// True when step `t`'s refresh runs inline and feeds the SAME step's
    /// update, so a distributed run must exchange the owner's publication
    /// mid-step (before non-owning ranks compute their direction). Must be a
    /// pure function of state replicated on every rank.
    fn dist_mid_step_sync(&self, t: u64) -> bool {
        let _ = t;
        false
    }

    /// Fold in any async-refresh result that has been published but not yet
    /// adopted (adoption normally happens at the next `update`). The
    /// checkpoint path calls this — after the refresh service is drained —
    /// so `export_state` captures exactly the state an uninterrupted run
    /// would use on its next step. Default no-op (inline optimizers have
    /// nothing pending).
    fn finish_pending(&mut self) {}

    /// Step at which the factor EMAs backing the *active* preconditioner
    /// were snapshotted — `t - basis_snapshot_step()` is the staleness the
    /// coordinator reports. `None` when the layer has no preconditioner
    /// (AdamW, identity-capped SOAP) or none has been built yet.
    fn basis_snapshot_step(&self) -> Option<u64> {
        None
    }

    /// Frobenius norm of the most recent preconditioned update direction
    /// (pre-`lr` scaling), for per-layer health metrics. `None` when the
    /// optimizer does not retain its last direction (monolithic baselines,
    /// PJRT) or has not stepped yet.
    fn update_norm(&self) -> Option<f64> {
        None
    }

    /// Whitening quality: off-diagonal mass ratio of the rotated second
    /// moment `QᵀLQ` at the most recent refresh (0 = the basis perfectly
    /// diagonalizes the factor — the property SOAP's rotation maintains).
    /// `None` for optimizers without a rotating basis, before the first
    /// refresh, or while telemetry is disabled (sampling is gated).
    fn whitening_offdiag(&self) -> Option<f64> {
        None
    }
}

/// Which optimizer to build (CLI/config surface): a named preset or a
/// [`CompositionSpec`] from the `basis=…,inner=…[,graft=…]` grammar.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptKind {
    AdamW,
    Adafactor,
    Shampoo,
    Soap,
    Galore,
    Composed(CompositionSpec),
}

/// The preset names accepted by [`OptKind::parse`], embedded in its errors.
pub const OPTIMIZER_NAMES: &str = "adamw (alias adam), adafactor, shampoo, soap, galore";

impl OptKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        // Anything carrying `key=value` pairs is a composition spec.
        if s.contains('=') {
            return Ok(OptKind::Composed(CompositionSpec::parse(s)?));
        }
        Ok(match s.to_ascii_lowercase().as_str() {
            "adamw" | "adam" => OptKind::AdamW,
            "adafactor" => OptKind::Adafactor,
            "shampoo" => OptKind::Shampoo,
            "soap" => OptKind::Soap,
            "galore" => OptKind::Galore,
            other => anyhow::bail!(
                "unknown optimizer '{other}': expected one of {OPTIMIZER_NAMES}, \
                 or a composition spec {}",
                compose::spec::GRAMMAR_HELP
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptKind::AdamW => "adamw",
            OptKind::Adafactor => "adafactor",
            OptKind::Shampoo => "shampoo",
            OptKind::Soap => "soap",
            OptKind::Galore => "galore",
            OptKind::Composed(spec) => spec.label(),
        }
    }

    /// A spelling that [`OptKind::parse`] maps back to this exact value —
    /// preset name for presets, the full `basis=…,inner=…[,graft=…]` grammar
    /// for composition specs. This is what `--dump-config` writes (labels
    /// like `soap-factorized` are display-only and do not parse).
    pub fn spec_string(&self) -> String {
        match self {
            OptKind::Composed(spec) => spec.spec_string(),
            k => k.name().to_string(),
        }
    }

    /// Collapse a composition spec onto the preset it is exactly equivalent
    /// to (identity for preset kinds and genuinely novel specs). Coordinators
    /// use this so `basis=eigen,inner=adam` rides every soap-only path (PJRT
    /// artifacts, tuned LRs) for free.
    pub fn canonical(&self) -> OptKind {
        match self {
            OptKind::Composed(spec) => spec.canonical().unwrap_or(*self),
            k => *k,
        }
    }

    /// Build per-layer state for an arbitrary-rank tensor parameter.
    ///
    /// Routing follows the paper's practical recipe: rank ≤ 2 takes the
    /// EXACT matrix path ([`Self::build`] — bitwise identical, pinned by
    /// `rust/tests/golden_tensor.rs`), rank ≥ 3 squeezes size-1 modes,
    /// applies `Hyper::merge_dims` adjacent-mode merging, and — when still
    /// rank ≥ 3 (or the merge changed the carrier fold) — preconditions
    /// per mode through [`compose::TensorEigenBasis`]. Optimizers without a
    /// per-mode decomposition (AdamW, Adafactor, GaLore) run on the 2-D
    /// carrier fold, which is the same elementwise math they always had.
    pub fn build_tensor(&self, shape: &TensorShape, h: &Hyper) -> Box<dyn LayerOptimizer> {
        let eff = shape.effective(h.merge_dims);
        let carrier = shape.carrier();
        if eff.rank() < 2 || (eff.rank() == 2 && eff.carrier() == carrier) {
            // Matrix path — covers every rank-≤2 parameter (where
            // `eff == shape`), rank-3+ shapes that collapse to a
            // carrier-preserving matrix (size-1 modes, merged modes), and
            // shapes that collapse all the way to a vector (an
            // over-aggressive `merge_dims`, or `[1, n, 1]`-style padding):
            // there is no per-mode structure left, so the 2-D carrier view
            // — with its own 1-D Adam fallback — is the optimizer.
            return self.build(carrier.0, carrier.1, h);
        }
        match self {
            OptKind::Soap => Box::new(compose::presets::soap_nd(carrier, &eff, h.clone())),
            OptKind::Shampoo => Box::new(compose::presets::shampoo_nd(carrier, &eff, h.clone())),
            // No per-mode decomposition to generalize — the carrier fold IS
            // their update rule (GaLore is defined on matrices; its
            // projector sees the carrier).
            OptKind::AdamW | OptKind::Adafactor | OptKind::Galore => {
                self.build(carrier.0, carrier.1, h)
            }
            OptKind::Composed(spec) => spec.build_tensor(shape, h),
        }
    }

    /// [`Self::build_tensor`] with the coordinator's staggered refresh phase
    /// applied (see [`Self::build_staggered`]).
    pub fn build_staggered_tensor(
        &self,
        layer_idx: usize,
        shape: &TensorShape,
        h: &Hyper,
    ) -> Box<dyn LayerOptimizer> {
        if !h.stagger_refresh {
            return self.build_tensor(shape, h);
        }
        let mut hl = h.clone();
        hl.refresh_phase = layer_idx as u64 % h.precond_freq.max(1);
        self.build_tensor(shape, &hl)
    }

    /// Build per-layer state for a parameter of shape `rows×cols`.
    ///
    /// Paper implementation detail 1: SOAP and GaLore run plain AdamW on 1-D
    /// parameters (unlike Shampoo, which preconditions them too).
    /// `Hyper::precondition_1d` opts SOAP back into preconditioning them —
    /// the reference implementation's `precondition_1d` knob (a 1-D param is
    /// a `1×n` matrix, whose 1×1 left factor is exact). GaLore keeps the
    /// fallback unconditionally: its gradient-SVD projector is degenerate on
    /// rank-1 inputs.
    pub fn build(&self, rows: usize, cols: usize, h: &Hyper) -> Box<dyn LayerOptimizer> {
        let is_1d = rows == 1 || cols == 1;
        match self {
            OptKind::AdamW => Box::new(AdamW::new(rows, cols, h.clone())),
            OptKind::Adafactor => Box::new(Adafactor::new(rows, cols, h.clone())),
            OptKind::Shampoo => Box::new(Shampoo::new(rows, cols, h.clone())),
            OptKind::Soap if is_1d && !h.precondition_1d => {
                Box::new(AdamW::new(rows, cols, h.clone()))
            }
            OptKind::Soap => Box::new(Soap::new(rows, cols, h.clone())),
            OptKind::Galore if is_1d => Box::new(AdamW::new(rows, cols, h.clone())),
            OptKind::Galore => Box::new(Galore::new(rows, cols, h.clone())),
            OptKind::Composed(spec) => spec.build(rows, cols, h),
        }
    }

    /// [`Self::build`] with the coordinator's staggered refresh phase
    /// (`layer_idx % f`) applied, so each layer recomputes its preconditioner
    /// on a different step and the `t ≡ 0 (mod f)` latency spike is spread
    /// out. Serial ([`ModelOptimizer`]) and sharded executors both use this,
    /// keeping their trajectories bitwise identical. An explicitly pinned
    /// phase (`Hyper::with_refresh_phase`, which clears `stagger_refresh`)
    /// is honored verbatim for every layer.
    pub fn build_staggered(
        &self,
        layer_idx: usize,
        rows: usize,
        cols: usize,
        h: &Hyper,
    ) -> Box<dyn LayerOptimizer> {
        if !h.stagger_refresh {
            return self.build(rows, cols, h);
        }
        let mut hl = h.clone();
        hl.refresh_phase = layer_idx as u64 % h.precond_freq.max(1);
        self.build(rows, cols, &hl)
    }
}

/// A full model's optimizer: one [`LayerOptimizer`] per parameter plus a
/// shared LR schedule and step counter.
pub struct ModelOptimizer {
    pub kind: OptKind,
    pub hyper: Hyper,
    pub schedule: Schedule,
    pub layers: Vec<Box<dyn LayerOptimizer>>,
    pub step: u64,
}

impl ModelOptimizer {
    pub fn new(kind: OptKind, hyper: Hyper, schedule: Schedule, shapes: &[(usize, usize)]) -> Self {
        let tshapes: Vec<TensorShape> =
            shapes.iter().map(|&(m, n)| TensorShape::matrix(m, n)).collect();
        Self::new_tensors(kind, hyper, schedule, &tshapes)
    }

    /// [`Self::new`] over arbitrary-rank parameter shapes. Rank-2 shapes
    /// build the identical matrix-path layers [`Self::new`] builds.
    pub fn new_tensors(
        kind: OptKind,
        hyper: Hyper,
        schedule: Schedule,
        shapes: &[TensorShape],
    ) -> Self {
        let layers = shapes
            .iter()
            .enumerate()
            .map(|(idx, shape)| kind.build_staggered_tensor(idx, shape, &hyper))
            .collect();
        Self { kind, hyper, schedule, layers, step: 0 }
    }

    /// One optimizer step over all layers (serial; the coordinator owns the
    /// parallel/sharded version).
    pub fn step(&mut self, params: &mut [Matrix], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.layers.len());
        self.step += 1;
        let lr = self.schedule.lr_at(self.step - 1);
        for ((layer, w), g) in self.layers.iter_mut().zip(params.iter_mut()).zip(grads) {
            layer.update(w, g, self.step, lr);
        }
    }

    /// Total optimizer-state bytes (paper §7.2 space-usage table).
    pub fn state_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.state_bytes()).sum()
    }

    /// Total workspace-arena bytes across layers (0 before the first step;
    /// grow-only afterwards).
    pub fn scratch_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.scratch_bytes()).sum()
    }

    pub fn refresh_seconds(&self) -> f64 {
        self.layers.iter().map(|l| l.refresh_seconds()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn factory_dispatches_1d_to_adamw_for_soap_galore() {
        let h = Hyper::default();
        assert_eq!(OptKind::Soap.build(1, 64, &h).name(), "adamw");
        assert_eq!(OptKind::Galore.build(1, 64, &h).name(), "adamw");
        assert_eq!(OptKind::Soap.build(8, 64, &h).name(), "soap");
        assert_eq!(OptKind::Shampoo.build(1, 64, &h).name(), "shampoo");
    }

    #[test]
    fn precondition_1d_routes_rank1_to_soap() {
        let h = Hyper::default().with_precondition_1d(true);
        assert_eq!(OptKind::Soap.build(1, 64, &h).name(), "soap");
        assert_eq!(OptKind::Soap.build(64, 1, &h).name(), "soap");
        // GaLore's SVD projector is degenerate on rank-1 inputs: fallback
        // stays regardless of the knob.
        assert_eq!(OptKind::Galore.build(1, 64, &h).name(), "adamw");
    }

    #[test]
    fn precondition_1d_off_is_bitwise_unchanged() {
        // `precondition_1d = false` must build the IDENTICAL AdamW fallback:
        // same updates, bit for bit, as a default-Hyper build.
        let h_def = Hyper::default();
        let h_off = Hyper::default().with_precondition_1d(false);
        let mut a = OptKind::Soap.build(1, 32, &h_def);
        let mut b = OptKind::Soap.build(1, 32, &h_off);
        let mut rng = Rng::new(7);
        let mut wa = Matrix::randn(&mut rng, 1, 32, 1.0);
        let mut wb = wa.clone();
        for t in 1..=20 {
            let g = Matrix::randn(&mut rng, 1, 32, 1.0);
            a.update(&mut wa, &g, t, 0.01);
            b.update(&mut wb, &g, t, 0.01);
        }
        assert_eq!(wa.data, wb.data, "knob off must not perturb the fallback path");
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(OptKind::parse("SOAP").unwrap(), OptKind::Soap);
        assert_eq!(OptKind::parse("adam").unwrap(), OptKind::AdamW);
        assert!(OptKind::parse("sgd").is_err());
    }

    #[test]
    fn parse_error_enumerates_valid_names_and_grammar() {
        let e = OptKind::parse("sgd").unwrap_err().to_string();
        for name in ["adamw", "adafactor", "shampoo", "soap", "galore", "basis="] {
            assert!(e.contains(name), "error should mention {name}: {e}");
        }
    }

    #[test]
    fn parse_composition_specs() {
        let k = OptKind::parse("basis=eigen,inner=adam").unwrap();
        assert_eq!(k.canonical(), OptKind::Soap);
        assert_eq!(k.name(), "soap");
        let k = OptKind::parse("basis=eigen:one-sided,inner=adafactor").unwrap();
        assert_eq!(k.canonical(), OptKind::Soap);
        assert_eq!(k.name(), "soap-factorized");
        let k = OptKind::parse("basis=svd,inner=adafactor").unwrap();
        assert_eq!(k.canonical(), k, "novel combos stay composed");
        assert!(OptKind::parse("basis=svd,inner=shampoo").is_err());
    }

    #[test]
    fn composed_spec_builds_through_optkind() {
        let h = Hyper::default();
        let k = OptKind::parse("basis=eigen:one-sided,inner=adafactor").unwrap();
        let opt = k.build(8, 4, &h);
        assert_eq!(opt.name(), "soap");
        assert_eq!(k.build(1, 16, &h).name(), "adamw");
    }

    #[test]
    fn model_optimizer_steps_all_layers() {
        let shapes = [(4, 4), (1, 8)];
        let mut mo = ModelOptimizer::new(
            OptKind::AdamW,
            Hyper::default(),
            Schedule::Constant { lr: 0.1 },
            &shapes,
        );
        let mut rng = Rng::new(1);
        let mut params: Vec<Matrix> = shapes
            .iter()
            .map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0))
            .collect();
        let before: Vec<Matrix> = params.clone();
        let grads: Vec<Matrix> = shapes
            .iter()
            .map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0))
            .collect();
        mo.step(&mut params, &grads);
        for (b, a) in before.iter().zip(&params) {
            assert!(b.max_abs_diff(a) > 0.0);
        }
        assert_eq!(mo.step, 1);
        assert!(mo.scratch_bytes() > 0, "workspace arenas should have grown after a step");
    }
}
