//! Optimizer hyperparameters — mirrors the paper's Appendix A defaults.

/// How SOAP/Shampoo recompute the preconditioner eigenbasis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefreshMethod {
    /// One power-iteration step + QR (paper Algorithm 4; default).
    QrPowerIteration,
    /// Fresh eigendecomposition every refresh (`torch.linalg.eigh` analogue;
    /// the slower arm of Fig 7 right).
    Eigh,
}

/// Hyperparameters shared across all optimizers. Per-optimizer fields are
/// ignored by optimizers that don't use them.
#[derive(Clone, Debug)]
pub struct Hyper {
    /// β₁ — first-moment EMA. Paper default 0.95.
    pub beta1: f32,
    /// β₂ — second-moment EMA (AdamW / SOAP's V). Paper default 0.95.
    pub beta2: f32,
    /// Adam/SOAP ε. Paper default 1e-8.
    pub eps: f32,
    /// Decoupled weight decay (Wortsman et al. style). Paper default 1e-4.
    pub weight_decay: f32,
    /// Preconditioning frequency f: eigenbasis / inverse-root recompute
    /// period in steps. Paper default 10.
    pub precond_freq: u64,
    /// β for the L/R Kronecker-factor EMAs (β_shampoo). Paper default 0.95.
    pub shampoo_beta: f32,
    /// Shampoo ε. Paper default 1e-12.
    pub shampoo_eps: f32,
    /// Shampoo inverse-exponent denominator: update uses L^{-1/e}, R^{-1/e}.
    /// Paper default e = 2.5 (DistributedShampoo's −1/2.5 finding);
    /// e = 2 is the "power 1/2" theoretical variant, e = 4 the original.
    pub shampoo_exponent: f32,
    /// Layerwise AdamW grafting for Shampoo (DistributedShampoo default).
    pub grafting: bool,
    /// SOAP: project only the smaller side (Q = I on the larger side) — §7.1.
    pub one_sided: bool,
    /// SOAP: Adafactor (rank-1) second moment in the eigenbasis — §7.2.1.
    pub factorized: bool,
    /// Dimensions larger than this keep Q = identity (paper implementation
    /// detail 3: embedding/output layers).
    pub max_precond_dim: usize,
    /// Eigenbasis refresh method (Fig 7 right ablation).
    pub refresh: RefreshMethod,
    /// GaLore update-scale α (appendix B; 1.0 for the full-rank version).
    pub galore_scale: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Self {
            beta1: 0.95,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 1e-4,
            precond_freq: 10,
            shampoo_beta: 0.95,
            shampoo_eps: 1e-12,
            shampoo_exponent: 2.5,
            grafting: true,
            one_sided: false,
            factorized: false,
            max_precond_dim: 4096,
            refresh: RefreshMethod::QrPowerIteration,
            galore_scale: 1.0,
        }
    }
}

impl Hyper {
    pub fn with_freq(mut self, f: u64) -> Self {
        self.precond_freq = f;
        self
    }
    pub fn one_sided(mut self) -> Self {
        self.one_sided = true;
        self
    }
    pub fn factorized(mut self) -> Self {
        self.factorized = true;
        self
    }
    pub fn with_refresh(mut self, r: RefreshMethod) -> Self {
        self.refresh = r;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_appendix_a() {
        let h = Hyper::default();
        assert_eq!(h.beta1, 0.95);
        assert_eq!(h.beta2, 0.95);
        assert_eq!(h.eps, 1e-8);
        assert_eq!(h.weight_decay, 1e-4);
        assert_eq!(h.precond_freq, 10);
        assert_eq!(h.shampoo_eps, 1e-12);
        assert_eq!(h.shampoo_exponent, 2.5);
    }

    #[test]
    fn builders_compose() {
        let h = Hyper::default().with_freq(80).one_sided().factorized();
        assert_eq!(h.precond_freq, 80);
        assert!(h.one_sided && h.factorized);
    }
}
