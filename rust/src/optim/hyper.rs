//! Optimizer hyperparameters — mirrors the paper's Appendix A defaults.

pub use crate::precond::RefreshMode;

/// How SOAP/Shampoo recompute the preconditioner eigenbasis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefreshMethod {
    /// One power-iteration step + QR (paper Algorithm 4; default).
    QrPowerIteration,
    /// Fresh eigendecomposition every refresh (`torch.linalg.eigh` analogue;
    /// the slower arm of Fig 7 right).
    Eigh,
}

impl RefreshMethod {
    /// Parse a CLI/config token. Errors enumerate the valid values.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "qr" | "power-iteration" | "qr-power-iteration" => RefreshMethod::QrPowerIteration,
            "eigh" => RefreshMethod::Eigh,
            other => anyhow::bail!(
                "unknown refresh method '{other}': expected qr (alias power-iteration) or eigh"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RefreshMethod::QrPowerIteration => "qr",
            RefreshMethod::Eigh => "eigh",
        }
    }
}

/// What to do when a gradient or update direction goes non-finite
/// (NaN/Inf). Parsed from `--guard` / the `guard` config key.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GuardPolicy {
    /// No checks at all — pre-guard behavior, NaNs propagate into the
    /// weights.
    Off,
    /// Skip the optimizer update for the poisoned step/layer; moments and
    /// weights for that update are left untouched, and
    /// `soap_step_skipped_total` counts the skip. Default: one bad batch
    /// costs one step, not the run.
    SkipStep,
    /// Zero non-finite elements and clamp the rest into `[-max, max]`, then
    /// proceed.
    Clip(f32),
    /// Surface a typed error and stop the run (strict-reproducibility mode).
    Abort,
}

impl GuardPolicy {
    /// Parse a CLI/config token: `off`, `skip-step`, `clip[:max]`, `abort`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let lower = s.to_ascii_lowercase();
        Ok(match lower.as_str() {
            "off" | "none" => GuardPolicy::Off,
            "skip-step" | "skip" => GuardPolicy::SkipStep,
            "abort" => GuardPolicy::Abort,
            other => match other.strip_prefix("clip") {
                Some("") => GuardPolicy::Clip(GuardPolicy::DEFAULT_CLIP),
                Some(rest) => {
                    let max: f32 = rest
                        .strip_prefix(':')
                        .and_then(|v| v.parse().ok())
                        .filter(|m: &f32| m.is_finite() && *m > 0.0)
                        .ok_or_else(|| {
                            anyhow::anyhow!("bad guard clip bound '{s}': expected clip:<max>")
                        })?;
                    GuardPolicy::Clip(max)
                }
                None => anyhow::bail!(
                    "unknown guard policy '{other}': expected off, skip-step, clip[:max], abort"
                ),
            },
        })
    }

    pub const DEFAULT_CLIP: f32 = 1.0e3;

    /// Canonical token accepted back by [`Self::parse`] (config round-trip).
    pub fn name(&self) -> String {
        match self {
            GuardPolicy::Off => "off".into(),
            GuardPolicy::SkipStep => "skip-step".into(),
            GuardPolicy::Clip(max) => format!("clip:{max}"),
            GuardPolicy::Abort => "abort".into(),
        }
    }
}

/// Storage precision for the *slow-moving* optimizer state: Kronecker-factor
/// EMAs (`L`/`R` and per-mode tensor factors) and Adam/Adafactor second
/// moments. Accumulation is always f32 — bf16 affects only what is stored
/// between steps (decode → f32 EMA → round-to-nearest-even encode), halving
/// `state_bytes` for those buffers (§7.2 accounting). Momentum, grafting
/// state, and eigenvector/root caches always stay f32.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateDtype {
    /// Full-precision storage (default) — the bitwise-pinned reference.
    F32,
    /// bf16 storage (u16 = top half of the f32 bits) with f32 accumulation.
    /// Changes trajectories (each EMA write rounds to 8 mantissa bits), so
    /// it is opt-in and tagged in checkpoints.
    Bf16,
}

impl StateDtype {
    /// Parse a CLI/config token: `f32` | `bf16`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => StateDtype::F32,
            "bf16" | "bfloat16" => StateDtype::Bf16,
            other => anyhow::bail!("unknown state dtype '{other}': expected f32 or bf16"),
        })
    }

    /// Canonical token accepted back by [`Self::parse`] (config round-trip).
    pub fn name(&self) -> &'static str {
        match self {
            StateDtype::F32 => "f32",
            StateDtype::Bf16 => "bf16",
        }
    }

    /// Bytes per stored element — the single source of truth for
    /// `state_bytes` accounting.
    pub fn bytes(&self) -> usize {
        match self {
            StateDtype::F32 => 4,
            StateDtype::Bf16 => 2,
        }
    }
}

/// Maximum number of pieces a [`FreqSchedule`] can hold. Fixed so the
/// schedule stays `Copy` and can ride inside `CompositionSpec` (which the
/// `Copy` `OptKind` embeds).
pub const MAX_FREQ_PIECES: usize = 8;

/// Piecewise-constant schedule for the preconditioning frequency — the
/// paper's Fig. 1 degradation experiment as a first-class knob. Each piece
/// `(start_step, freq)` means "from step `start_step` onward, refresh every
/// `freq` steps"; pieces are sorted by strictly increasing `start_step`.
/// Steps before the first piece fall back to the base `precond_freq`.
///
/// Parsed from `freq@start` lists: `10@0,100@1000` (the composition grammar
/// uses `;` instead of `,` since `,` separates grammar keys).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FreqSchedule {
    len: usize,
    pieces: [(u64, u64); MAX_FREQ_PIECES],
}

impl FreqSchedule {
    /// Build from `(start_step, freq)` pieces. Errors on empty input, more
    /// than [`MAX_FREQ_PIECES`] pieces, non-increasing starts, or zero
    /// frequencies.
    pub fn new(pieces: &[(u64, u64)]) -> anyhow::Result<Self> {
        anyhow::ensure!(!pieces.is_empty(), "frequency schedule needs at least one piece");
        anyhow::ensure!(
            pieces.len() <= MAX_FREQ_PIECES,
            "frequency schedule holds at most {MAX_FREQ_PIECES} pieces, got {}",
            pieces.len()
        );
        let mut buf = [(0u64, 0u64); MAX_FREQ_PIECES];
        for (i, &(start, freq)) in pieces.iter().enumerate() {
            anyhow::ensure!(freq > 0, "frequency schedule piece {i} has freq 0");
            if i > 0 {
                anyhow::ensure!(
                    start > pieces[i - 1].0,
                    "frequency schedule starts must be strictly increasing \
                     ({start} after {})",
                    pieces[i - 1].0
                );
            }
            buf[i] = (start, freq);
        }
        Ok(FreqSchedule { len: pieces.len(), pieces: buf })
    }

    /// Parse a `freq@start` list: `10@0,100@1000` or `10@0;100@1000`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let mut pieces = Vec::new();
        for tok in s.split([',', ';']).map(str::trim).filter(|t| !t.is_empty()) {
            let (freq, start) = tok.split_once('@').ok_or_else(|| {
                anyhow::anyhow!("bad schedule piece '{tok}': expected freq@start (e.g. 10@0)")
            })?;
            let freq: u64 = freq
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad frequency '{freq}' in piece '{tok}'"))?;
            let start: u64 = start
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad start step '{start}' in piece '{tok}'"))?;
            pieces.push((start, freq));
        }
        FreqSchedule::new(&pieces)
    }

    /// The active `(start_step, freq)` pieces, in start order.
    pub fn pieces(&self) -> &[(u64, u64)] {
        &self.pieces[..self.len]
    }

    /// Frequency in force at step `t`, or `None` when `t` precedes the
    /// first piece (caller falls back to the base `precond_freq`).
    pub fn freq_at(&self, t: u64) -> Option<u64> {
        let mut out = None;
        for &(start, freq) in self.pieces() {
            if t >= start {
                out = Some(freq);
            }
        }
        out
    }

    /// Canonical `freq@start` form with `sep` between pieces; `parse`
    /// accepts it back (config round-trip).
    pub fn spec_string(&self, sep: char) -> String {
        let mut out = String::new();
        for (i, &(start, freq)) in self.pieces().iter().enumerate() {
            if i > 0 {
                out.push(sep);
            }
            use std::fmt::Write as _;
            let _ = write!(out, "{freq}@{start}");
        }
        out
    }
}

/// Hyperparameters shared across all optimizers. Per-optimizer fields are
/// ignored by optimizers that don't use them.
#[derive(Clone, Debug)]
pub struct Hyper {
    /// β₁ — first-moment EMA. Paper default 0.95.
    pub beta1: f32,
    /// β₂ — second-moment EMA (AdamW / SOAP's V). Paper default 0.95.
    pub beta2: f32,
    /// Adam/SOAP ε. Paper default 1e-8.
    pub eps: f32,
    /// Decoupled weight decay (Wortsman et al. style). Paper default 1e-4.
    pub weight_decay: f32,
    /// Preconditioning frequency f: eigenbasis / inverse-root recompute
    /// period in steps. Paper default 10.
    pub precond_freq: u64,
    /// Optional piecewise schedule overriding `precond_freq` per step range
    /// (`10@0,100@1000` — start cheap and accurate, relax later; paper
    /// Fig. 1). `None` (default) keeps the constant `precond_freq`. Stagger
    /// phases and the config fingerprint still derive from the base
    /// `precond_freq`.
    pub precond_freq_schedule: Option<FreqSchedule>,
    /// Precondition rank-1 parameters (bias/gain vectors) instead of routing
    /// them to the AdamW fallback — the reference SOAP implementation's
    /// `precondition_1d` knob. A 1-D param becomes a 1×n matrix whose 1×1
    /// left factor is exact, so this is the official one-sided treatment.
    /// Default false (paper implementation detail 1: Adam fallback).
    pub precondition_1d: bool,
    /// β for the L/R Kronecker-factor EMAs (β_shampoo). Paper default 0.95.
    pub shampoo_beta: f32,
    /// Shampoo ε. Paper default 1e-12.
    pub shampoo_eps: f32,
    /// Shampoo inverse-exponent denominator: update uses L^{-1/e}, R^{-1/e}.
    /// Paper default e = 2.5 (DistributedShampoo's −1/2.5 finding);
    /// e = 2 is the "power 1/2" theoretical variant, e = 4 the original.
    pub shampoo_exponent: f32,
    /// Layerwise AdamW grafting for Shampoo (DistributedShampoo default).
    pub grafting: bool,
    /// SOAP: project only the smaller side (Q = I on the larger side) — §7.1.
    pub one_sided: bool,
    /// SOAP: Adafactor (rank-1) second moment in the eigenbasis — §7.2.1.
    pub factorized: bool,
    /// Dimensions larger than this keep Q = identity (paper implementation
    /// detail 3: embedding/output layers). Applies per mode for rank-3+
    /// tensors; a dimension EQUAL to the cap is still preconditioned.
    pub max_precond_dim: usize,
    /// Rank-3+ tensors: merge adjacent modes while the merged size stays ≤
    /// this (`merge_small_dims` in DistributedShampoo) before building the
    /// per-mode basis — fewer, larger factors. 0 disables merging (default).
    /// Never applied to rank-≤2 parameters, whose matrix path is the
    /// bitwise-pinned reference.
    pub merge_dims: usize,
    /// Eigenbasis refresh method (Fig 7 right ablation).
    pub refresh: RefreshMethod,
    /// Refresh execution mode: `Inline` (synchronous, deterministic) or
    /// `Async` (background `precond::RefreshService`).
    pub refresh_mode: RefreshMode,
    /// Per-layer refresh phase offset φ ∈ [0, f): the refresh fires when
    /// `t ≡ φ (mod f)`. While `stagger_refresh` is set (the default) the
    /// coordinator OVERWRITES this per layer with `layer_idx % f`; clear
    /// `stagger_refresh` to pin an explicit phase (0 = the all-at-once
    /// pre-stagger schedule).
    pub refresh_phase: u64,
    /// Let the coordinator stagger per-layer refresh phases (`layer_idx %
    /// f`) so layers don't all refresh (or enqueue) on the same step.
    /// Default true; disable to honor `refresh_phase` verbatim.
    pub stagger_refresh: bool,
    /// Dedicated worker threads for the async refresh service (used only
    /// when `refresh_mode == Async`).
    pub refresh_workers: usize,
    /// GaLore update-scale α (appendix B; 1.0 for the full-rank version).
    pub galore_scale: f32,
    /// Pure-Adam ramp: while `t ≤ adam_warmup_steps` the eigenbasis neither
    /// accumulates factor statistics nor refreshes, so SOAP/Shampoo run
    /// exactly AdamW math (identity basis) and the first basis is built
    /// fresh from the first post-warmup gradient. 0 (default) disables.
    pub adam_warmup_steps: u64,
    /// Refresh-every-step early phase: while `t ≤ precondition_warmup`
    /// every step is a refresh step regardless of `precond_freq`, matching
    /// the production recipe of keeping the basis exact while statistics
    /// are still moving fast. 0 (default) disables.
    pub precondition_warmup: u64,
    /// Numerical-health response when a gradient or update direction goes
    /// non-finite. Default [`GuardPolicy::SkipStep`]: drop the poisoned
    /// update, keep the run alive.
    pub guard: GuardPolicy,
    /// Storage precision for factor EMAs and second moments
    /// (`--state-dtype`). Default [`StateDtype::F32`]; bf16 halves their
    /// `state_bytes` at the cost of rounding each EMA write.
    pub state_dtype: StateDtype,
}

impl Default for Hyper {
    fn default() -> Self {
        Self {
            beta1: 0.95,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 1e-4,
            precond_freq: 10,
            precond_freq_schedule: None,
            precondition_1d: false,
            shampoo_beta: 0.95,
            shampoo_eps: 1e-12,
            shampoo_exponent: 2.5,
            grafting: true,
            one_sided: false,
            factorized: false,
            max_precond_dim: 4096,
            merge_dims: 0,
            refresh: RefreshMethod::QrPowerIteration,
            refresh_mode: RefreshMode::Inline,
            refresh_phase: 0,
            stagger_refresh: true,
            refresh_workers: 2,
            galore_scale: 1.0,
            adam_warmup_steps: 0,
            precondition_warmup: 0,
            guard: GuardPolicy::SkipStep,
            state_dtype: StateDtype::F32,
        }
    }
}

impl Hyper {
    pub fn with_freq(mut self, f: u64) -> Self {
        self.precond_freq = f;
        self
    }
    /// Install a piecewise preconditioning-frequency schedule.
    pub fn with_freq_schedule(mut self, s: FreqSchedule) -> Self {
        self.precond_freq_schedule = Some(s);
        self
    }
    /// Precondition rank-1 params instead of the AdamW fallback.
    pub fn with_precondition_1d(mut self, on: bool) -> Self {
        self.precondition_1d = on;
        self
    }
    pub fn one_sided(mut self) -> Self {
        self.one_sided = true;
        self
    }
    pub fn factorized(mut self) -> Self {
        self.factorized = true;
        self
    }
    pub fn with_refresh(mut self, r: RefreshMethod) -> Self {
        self.refresh = r;
        self
    }
    /// Set the adjacent-mode merge threshold for rank-3+ tensors.
    pub fn with_merge_dims(mut self, cap: usize) -> Self {
        self.merge_dims = cap;
        self
    }
    /// Set the per-mode preconditioning dim cap.
    pub fn with_max_precond_dim(mut self, cap: usize) -> Self {
        self.max_precond_dim = cap;
        self
    }
    pub fn async_refresh(mut self) -> Self {
        self.refresh_mode = RefreshMode::Async;
        self
    }
    pub fn with_refresh_mode(mut self, m: RefreshMode) -> Self {
        self.refresh_mode = m;
        self
    }
    /// Pin the phase φ at which refreshes fire (`t ≡ φ (mod f)`) — also
    /// disables the coordinator's per-layer staggering, which would
    /// otherwise overwrite it. `with_refresh_phase(0)` reproduces the
    /// pre-stagger all-at-once schedule.
    pub fn with_refresh_phase(mut self, phase: u64) -> Self {
        self.refresh_phase = phase;
        self.stagger_refresh = false;
        self
    }
    /// Pure-Adam ramp length (steps before the eigenbasis starts).
    pub fn with_adam_warmup(mut self, steps: u64) -> Self {
        self.adam_warmup_steps = steps;
        self
    }
    /// Refresh-every-step early-phase length.
    pub fn with_precondition_warmup(mut self, steps: u64) -> Self {
        self.precondition_warmup = steps;
        self
    }
    /// Non-finite gradient/direction response policy.
    pub fn with_guard(mut self, guard: GuardPolicy) -> Self {
        self.guard = guard;
        self
    }
    /// Storage precision for factor EMAs and second moments.
    pub fn with_state_dtype(mut self, d: StateDtype) -> Self {
        self.state_dtype = d;
        self
    }
    /// Preconditioning frequency in force at step `t`: the schedule piece
    /// covering `t` when one is installed, else the base `precond_freq`.
    /// Never 0.
    pub fn precond_freq_at(&self, t: u64) -> u64 {
        self.precond_freq_schedule
            .as_ref()
            .and_then(|s| s.freq_at(t))
            .unwrap_or(self.precond_freq)
            .max(1)
    }
    /// Does step `t` (1-based) hit this layer's refresh phase? Every step
    /// inside the `precondition_warmup` window refreshes regardless of the
    /// phase schedule; a [`FreqSchedule`] swaps the modulus at its piece
    /// boundaries.
    pub fn is_refresh_step(&self, t: u64) -> bool {
        if t <= self.precondition_warmup {
            return true;
        }
        let f = self.precond_freq_at(t);
        t % f == self.refresh_phase % f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_appendix_a() {
        let h = Hyper::default();
        assert_eq!(h.beta1, 0.95);
        assert_eq!(h.beta2, 0.95);
        assert_eq!(h.eps, 1e-8);
        assert_eq!(h.weight_decay, 1e-4);
        assert_eq!(h.precond_freq, 10);
        assert_eq!(h.shampoo_eps, 1e-12);
        assert_eq!(h.shampoo_exponent, 2.5);
    }

    #[test]
    fn builders_compose() {
        let h = Hyper::default().with_freq(80).one_sided().factorized();
        assert_eq!(h.precond_freq, 80);
        assert!(h.one_sided && h.factorized);
        let h = h.async_refresh().with_refresh_phase(3);
        assert_eq!(h.refresh_mode, RefreshMode::Async);
        assert_eq!(h.refresh_phase, 3);
    }

    #[test]
    fn refresh_method_parse_enumerates_choices() {
        assert_eq!(RefreshMethod::parse("QR").unwrap(), RefreshMethod::QrPowerIteration);
        assert_eq!(RefreshMethod::parse("eigh").unwrap(), RefreshMethod::Eigh);
        let e = RefreshMethod::parse("svd").unwrap_err().to_string();
        assert!(e.contains("qr") && e.contains("eigh"), "{e}");
    }

    #[test]
    fn refresh_step_respects_phase() {
        let h = Hyper::default().with_freq(10);
        assert!(h.is_refresh_step(10) && h.is_refresh_step(20));
        assert!(!h.is_refresh_step(11));
        let h = h.with_refresh_phase(3);
        assert!(h.is_refresh_step(3) && h.is_refresh_step(13));
        assert!(!h.is_refresh_step(10));
        // Phase ≥ f wraps.
        let h = Hyper::default().with_freq(4).with_refresh_phase(6);
        assert!(h.is_refresh_step(2) && h.is_refresh_step(6));
    }

    #[test]
    fn precondition_warmup_refreshes_every_early_step() {
        let h = Hyper::default().with_freq(10).with_precondition_warmup(5);
        for t in 1..=5 {
            assert!(h.is_refresh_step(t), "step {t} inside the warmup must refresh");
        }
        assert!(!h.is_refresh_step(6));
        assert!(h.is_refresh_step(10));
    }

    #[test]
    fn warmup_builders_default_off() {
        let h = Hyper::default();
        assert_eq!(h.adam_warmup_steps, 0);
        assert_eq!(h.precondition_warmup, 0);
        let h = h.with_adam_warmup(50).with_precondition_warmup(9);
        assert_eq!(h.adam_warmup_steps, 50);
        assert_eq!(h.precondition_warmup, 9);
    }

    #[test]
    fn freq_schedule_parses_and_round_trips() {
        let s = FreqSchedule::parse("10@0,100@1000").unwrap();
        assert_eq!(s.pieces(), &[(0, 10), (1000, 100)]);
        assert_eq!(s.spec_string(','), "10@0,100@1000");
        assert_eq!(FreqSchedule::parse(&s.spec_string(';')).unwrap(), s);
        assert_eq!(FreqSchedule::parse("10@0;100@1000").unwrap(), s);
        for bad in ["", "10", "10@", "@0", "0@0", "10@5,100@5", "10@5,100@2"] {
            assert!(FreqSchedule::parse(bad).is_err(), "{bad:?} must be rejected");
        }
        let too_many = (0..9).map(|i| format!("2@{i}")).collect::<Vec<_>>().join(",");
        assert!(FreqSchedule::parse(&too_many).is_err());
    }

    #[test]
    fn freq_schedule_switches_at_boundary() {
        // Golden expectations around the switch: f=4 from step 0, f=10 from
        // step 20. Step 20 itself already uses the new modulus.
        let h = Hyper::default()
            .with_freq_schedule(FreqSchedule::parse("4@0,10@20").unwrap())
            .with_refresh_phase(0);
        let refreshes: Vec<u64> = (1..=40).filter(|&t| h.is_refresh_step(t)).collect();
        assert_eq!(refreshes, vec![4, 8, 12, 16, 20, 30, 40]);
        assert_eq!(h.precond_freq_at(19), 4);
        assert_eq!(h.precond_freq_at(20), 10);
        // Steps before the first piece fall back to the base frequency.
        let h = Hyper::default()
            .with_freq(3)
            .with_freq_schedule(FreqSchedule::parse("5@10").unwrap())
            .with_refresh_phase(0);
        assert_eq!(h.precond_freq_at(9), 3);
        assert_eq!(h.precond_freq_at(10), 5);
        // A single-piece schedule from step 0 is exactly the constant case.
        let sched = Hyper::default()
            .with_freq_schedule(FreqSchedule::parse("10@0").unwrap())
            .with_refresh_phase(0);
        let constant = Hyper::default().with_freq(10).with_refresh_phase(0);
        for t in 1..=100 {
            assert_eq!(sched.is_refresh_step(t), constant.is_refresh_step(t), "step {t}");
        }
    }

    #[test]
    fn precondition_1d_defaults_off() {
        assert!(!Hyper::default().precondition_1d);
        assert!(Hyper::default().with_precondition_1d(true).precondition_1d);
    }

    #[test]
    fn guard_policy_parses_and_round_trips() {
        assert_eq!(Hyper::default().guard, GuardPolicy::SkipStep);
        for (token, want) in [
            ("off", GuardPolicy::Off),
            ("none", GuardPolicy::Off),
            ("skip-step", GuardPolicy::SkipStep),
            ("skip", GuardPolicy::SkipStep),
            ("clip", GuardPolicy::Clip(GuardPolicy::DEFAULT_CLIP)),
            ("clip:2.5", GuardPolicy::Clip(2.5)),
            ("abort", GuardPolicy::Abort),
            ("ABORT", GuardPolicy::Abort),
        ] {
            let got = GuardPolicy::parse(token).unwrap();
            assert_eq!(got, want, "token {token:?}");
            // name() must be accepted back by parse() (config dump/load).
            assert_eq!(GuardPolicy::parse(&got.name()).unwrap(), got);
        }
        for bad in ["", "klip", "clip:", "clip:-1", "clip:nan", "skipstep"] {
            assert!(GuardPolicy::parse(bad).is_err(), "token {bad:?} must be rejected");
        }
    }

    #[test]
    fn state_dtype_parses_and_round_trips() {
        assert_eq!(Hyper::default().state_dtype, StateDtype::F32);
        assert_eq!(
            Hyper::default().with_state_dtype(StateDtype::Bf16).state_dtype,
            StateDtype::Bf16
        );
        for (token, want) in [
            ("f32", StateDtype::F32),
            ("FP32", StateDtype::F32),
            ("float32", StateDtype::F32),
            ("bf16", StateDtype::Bf16),
            ("BFLOAT16", StateDtype::Bf16),
        ] {
            let got = StateDtype::parse(token).unwrap();
            assert_eq!(got, want, "token {token:?}");
            assert_eq!(StateDtype::parse(got.name()).unwrap(), got);
        }
        assert_eq!(StateDtype::F32.bytes(), 4);
        assert_eq!(StateDtype::Bf16.bytes(), 2);
        for bad in ["", "f16", "fp16", "half", "b16"] {
            assert!(StateDtype::parse(bad).is_err(), "token {bad:?} must be rejected");
        }
    }
}
