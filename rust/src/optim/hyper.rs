//! Optimizer hyperparameters — mirrors the paper's Appendix A defaults.

pub use crate::precond::RefreshMode;

/// How SOAP/Shampoo recompute the preconditioner eigenbasis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefreshMethod {
    /// One power-iteration step + QR (paper Algorithm 4; default).
    QrPowerIteration,
    /// Fresh eigendecomposition every refresh (`torch.linalg.eigh` analogue;
    /// the slower arm of Fig 7 right).
    Eigh,
}

impl RefreshMethod {
    /// Parse a CLI/config token. Errors enumerate the valid values.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "qr" | "power-iteration" | "qr-power-iteration" => RefreshMethod::QrPowerIteration,
            "eigh" => RefreshMethod::Eigh,
            other => anyhow::bail!(
                "unknown refresh method '{other}': expected qr (alias power-iteration) or eigh"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RefreshMethod::QrPowerIteration => "qr",
            RefreshMethod::Eigh => "eigh",
        }
    }
}

/// What to do when a gradient or update direction goes non-finite
/// (NaN/Inf). Parsed from `--guard` / the `guard` config key.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GuardPolicy {
    /// No checks at all — pre-guard behavior, NaNs propagate into the
    /// weights.
    Off,
    /// Skip the optimizer update for the poisoned step/layer; moments and
    /// weights for that update are left untouched, and
    /// `soap_step_skipped_total` counts the skip. Default: one bad batch
    /// costs one step, not the run.
    SkipStep,
    /// Zero non-finite elements and clamp the rest into `[-max, max]`, then
    /// proceed.
    Clip(f32),
    /// Surface a typed error and stop the run (strict-reproducibility mode).
    Abort,
}

impl GuardPolicy {
    /// Parse a CLI/config token: `off`, `skip-step`, `clip[:max]`, `abort`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let lower = s.to_ascii_lowercase();
        Ok(match lower.as_str() {
            "off" | "none" => GuardPolicy::Off,
            "skip-step" | "skip" => GuardPolicy::SkipStep,
            "abort" => GuardPolicy::Abort,
            other => match other.strip_prefix("clip") {
                Some("") => GuardPolicy::Clip(GuardPolicy::DEFAULT_CLIP),
                Some(rest) => {
                    let max: f32 = rest
                        .strip_prefix(':')
                        .and_then(|v| v.parse().ok())
                        .filter(|m: &f32| m.is_finite() && *m > 0.0)
                        .ok_or_else(|| {
                            anyhow::anyhow!("bad guard clip bound '{s}': expected clip:<max>")
                        })?;
                    GuardPolicy::Clip(max)
                }
                None => anyhow::bail!(
                    "unknown guard policy '{other}': expected off, skip-step, clip[:max], abort"
                ),
            },
        })
    }

    pub const DEFAULT_CLIP: f32 = 1.0e3;

    /// Canonical token accepted back by [`Self::parse`] (config round-trip).
    pub fn name(&self) -> String {
        match self {
            GuardPolicy::Off => "off".into(),
            GuardPolicy::SkipStep => "skip-step".into(),
            GuardPolicy::Clip(max) => format!("clip:{max}"),
            GuardPolicy::Abort => "abort".into(),
        }
    }
}

/// Hyperparameters shared across all optimizers. Per-optimizer fields are
/// ignored by optimizers that don't use them.
#[derive(Clone, Debug)]
pub struct Hyper {
    /// β₁ — first-moment EMA. Paper default 0.95.
    pub beta1: f32,
    /// β₂ — second-moment EMA (AdamW / SOAP's V). Paper default 0.95.
    pub beta2: f32,
    /// Adam/SOAP ε. Paper default 1e-8.
    pub eps: f32,
    /// Decoupled weight decay (Wortsman et al. style). Paper default 1e-4.
    pub weight_decay: f32,
    /// Preconditioning frequency f: eigenbasis / inverse-root recompute
    /// period in steps. Paper default 10.
    pub precond_freq: u64,
    /// β for the L/R Kronecker-factor EMAs (β_shampoo). Paper default 0.95.
    pub shampoo_beta: f32,
    /// Shampoo ε. Paper default 1e-12.
    pub shampoo_eps: f32,
    /// Shampoo inverse-exponent denominator: update uses L^{-1/e}, R^{-1/e}.
    /// Paper default e = 2.5 (DistributedShampoo's −1/2.5 finding);
    /// e = 2 is the "power 1/2" theoretical variant, e = 4 the original.
    pub shampoo_exponent: f32,
    /// Layerwise AdamW grafting for Shampoo (DistributedShampoo default).
    pub grafting: bool,
    /// SOAP: project only the smaller side (Q = I on the larger side) — §7.1.
    pub one_sided: bool,
    /// SOAP: Adafactor (rank-1) second moment in the eigenbasis — §7.2.1.
    pub factorized: bool,
    /// Dimensions larger than this keep Q = identity (paper implementation
    /// detail 3: embedding/output layers). Applies per mode for rank-3+
    /// tensors; a dimension EQUAL to the cap is still preconditioned.
    pub max_precond_dim: usize,
    /// Rank-3+ tensors: merge adjacent modes while the merged size stays ≤
    /// this (`merge_small_dims` in DistributedShampoo) before building the
    /// per-mode basis — fewer, larger factors. 0 disables merging (default).
    /// Never applied to rank-≤2 parameters, whose matrix path is the
    /// bitwise-pinned reference.
    pub merge_dims: usize,
    /// Eigenbasis refresh method (Fig 7 right ablation).
    pub refresh: RefreshMethod,
    /// Refresh execution mode: `Inline` (synchronous, deterministic) or
    /// `Async` (background `precond::RefreshService`).
    pub refresh_mode: RefreshMode,
    /// Per-layer refresh phase offset φ ∈ [0, f): the refresh fires when
    /// `t ≡ φ (mod f)`. While `stagger_refresh` is set (the default) the
    /// coordinator OVERWRITES this per layer with `layer_idx % f`; clear
    /// `stagger_refresh` to pin an explicit phase (0 = the all-at-once
    /// pre-stagger schedule).
    pub refresh_phase: u64,
    /// Let the coordinator stagger per-layer refresh phases (`layer_idx %
    /// f`) so layers don't all refresh (or enqueue) on the same step.
    /// Default true; disable to honor `refresh_phase` verbatim.
    pub stagger_refresh: bool,
    /// Dedicated worker threads for the async refresh service (used only
    /// when `refresh_mode == Async`).
    pub refresh_workers: usize,
    /// GaLore update-scale α (appendix B; 1.0 for the full-rank version).
    pub galore_scale: f32,
    /// Pure-Adam ramp: while `t ≤ adam_warmup_steps` the eigenbasis neither
    /// accumulates factor statistics nor refreshes, so SOAP/Shampoo run
    /// exactly AdamW math (identity basis) and the first basis is built
    /// fresh from the first post-warmup gradient. 0 (default) disables.
    pub adam_warmup_steps: u64,
    /// Refresh-every-step early phase: while `t ≤ precondition_warmup`
    /// every step is a refresh step regardless of `precond_freq`, matching
    /// the production recipe of keeping the basis exact while statistics
    /// are still moving fast. 0 (default) disables.
    pub precondition_warmup: u64,
    /// Numerical-health response when a gradient or update direction goes
    /// non-finite. Default [`GuardPolicy::SkipStep`]: drop the poisoned
    /// update, keep the run alive.
    pub guard: GuardPolicy,
}

impl Default for Hyper {
    fn default() -> Self {
        Self {
            beta1: 0.95,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 1e-4,
            precond_freq: 10,
            shampoo_beta: 0.95,
            shampoo_eps: 1e-12,
            shampoo_exponent: 2.5,
            grafting: true,
            one_sided: false,
            factorized: false,
            max_precond_dim: 4096,
            merge_dims: 0,
            refresh: RefreshMethod::QrPowerIteration,
            refresh_mode: RefreshMode::Inline,
            refresh_phase: 0,
            stagger_refresh: true,
            refresh_workers: 2,
            galore_scale: 1.0,
            adam_warmup_steps: 0,
            precondition_warmup: 0,
            guard: GuardPolicy::SkipStep,
        }
    }
}

impl Hyper {
    pub fn with_freq(mut self, f: u64) -> Self {
        self.precond_freq = f;
        self
    }
    pub fn one_sided(mut self) -> Self {
        self.one_sided = true;
        self
    }
    pub fn factorized(mut self) -> Self {
        self.factorized = true;
        self
    }
    pub fn with_refresh(mut self, r: RefreshMethod) -> Self {
        self.refresh = r;
        self
    }
    /// Set the adjacent-mode merge threshold for rank-3+ tensors.
    pub fn with_merge_dims(mut self, cap: usize) -> Self {
        self.merge_dims = cap;
        self
    }
    /// Set the per-mode preconditioning dim cap.
    pub fn with_max_precond_dim(mut self, cap: usize) -> Self {
        self.max_precond_dim = cap;
        self
    }
    pub fn async_refresh(mut self) -> Self {
        self.refresh_mode = RefreshMode::Async;
        self
    }
    pub fn with_refresh_mode(mut self, m: RefreshMode) -> Self {
        self.refresh_mode = m;
        self
    }
    /// Pin the phase φ at which refreshes fire (`t ≡ φ (mod f)`) — also
    /// disables the coordinator's per-layer staggering, which would
    /// otherwise overwrite it. `with_refresh_phase(0)` reproduces the
    /// pre-stagger all-at-once schedule.
    pub fn with_refresh_phase(mut self, phase: u64) -> Self {
        self.refresh_phase = phase;
        self.stagger_refresh = false;
        self
    }
    /// Pure-Adam ramp length (steps before the eigenbasis starts).
    pub fn with_adam_warmup(mut self, steps: u64) -> Self {
        self.adam_warmup_steps = steps;
        self
    }
    /// Refresh-every-step early-phase length.
    pub fn with_precondition_warmup(mut self, steps: u64) -> Self {
        self.precondition_warmup = steps;
        self
    }
    /// Non-finite gradient/direction response policy.
    pub fn with_guard(mut self, guard: GuardPolicy) -> Self {
        self.guard = guard;
        self
    }
    /// Does step `t` (1-based) hit this layer's refresh phase? Every step
    /// inside the `precondition_warmup` window refreshes regardless of the
    /// phase schedule.
    pub fn is_refresh_step(&self, t: u64) -> bool {
        if t <= self.precondition_warmup {
            return true;
        }
        let f = self.precond_freq.max(1);
        t % f == self.refresh_phase % f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_appendix_a() {
        let h = Hyper::default();
        assert_eq!(h.beta1, 0.95);
        assert_eq!(h.beta2, 0.95);
        assert_eq!(h.eps, 1e-8);
        assert_eq!(h.weight_decay, 1e-4);
        assert_eq!(h.precond_freq, 10);
        assert_eq!(h.shampoo_eps, 1e-12);
        assert_eq!(h.shampoo_exponent, 2.5);
    }

    #[test]
    fn builders_compose() {
        let h = Hyper::default().with_freq(80).one_sided().factorized();
        assert_eq!(h.precond_freq, 80);
        assert!(h.one_sided && h.factorized);
        let h = h.async_refresh().with_refresh_phase(3);
        assert_eq!(h.refresh_mode, RefreshMode::Async);
        assert_eq!(h.refresh_phase, 3);
    }

    #[test]
    fn refresh_method_parse_enumerates_choices() {
        assert_eq!(RefreshMethod::parse("QR").unwrap(), RefreshMethod::QrPowerIteration);
        assert_eq!(RefreshMethod::parse("eigh").unwrap(), RefreshMethod::Eigh);
        let e = RefreshMethod::parse("svd").unwrap_err().to_string();
        assert!(e.contains("qr") && e.contains("eigh"), "{e}");
    }

    #[test]
    fn refresh_step_respects_phase() {
        let h = Hyper::default().with_freq(10);
        assert!(h.is_refresh_step(10) && h.is_refresh_step(20));
        assert!(!h.is_refresh_step(11));
        let h = h.with_refresh_phase(3);
        assert!(h.is_refresh_step(3) && h.is_refresh_step(13));
        assert!(!h.is_refresh_step(10));
        // Phase ≥ f wraps.
        let h = Hyper::default().with_freq(4).with_refresh_phase(6);
        assert!(h.is_refresh_step(2) && h.is_refresh_step(6));
    }

    #[test]
    fn precondition_warmup_refreshes_every_early_step() {
        let h = Hyper::default().with_freq(10).with_precondition_warmup(5);
        for t in 1..=5 {
            assert!(h.is_refresh_step(t), "step {t} inside the warmup must refresh");
        }
        assert!(!h.is_refresh_step(6));
        assert!(h.is_refresh_step(10));
    }

    #[test]
    fn warmup_builders_default_off() {
        let h = Hyper::default();
        assert_eq!(h.adam_warmup_steps, 0);
        assert_eq!(h.precondition_warmup, 0);
        let h = h.with_adam_warmup(50).with_precondition_warmup(9);
        assert_eq!(h.adam_warmup_steps, 50);
        assert_eq!(h.precondition_warmup, 9);
    }

    #[test]
    fn guard_policy_parses_and_round_trips() {
        assert_eq!(Hyper::default().guard, GuardPolicy::SkipStep);
        for (token, want) in [
            ("off", GuardPolicy::Off),
            ("none", GuardPolicy::Off),
            ("skip-step", GuardPolicy::SkipStep),
            ("skip", GuardPolicy::SkipStep),
            ("clip", GuardPolicy::Clip(GuardPolicy::DEFAULT_CLIP)),
            ("clip:2.5", GuardPolicy::Clip(2.5)),
            ("abort", GuardPolicy::Abort),
            ("ABORT", GuardPolicy::Abort),
        ] {
            let got = GuardPolicy::parse(token).unwrap();
            assert_eq!(got, want, "token {token:?}");
            // name() must be accepted back by parse() (config dump/load).
            assert_eq!(GuardPolicy::parse(&got.name()).unwrap(), got);
        }
        for bad in ["", "klip", "clip:", "clip:-1", "clip:nan", "skipstep"] {
            assert!(GuardPolicy::parse(bad).is_err(), "token {bad:?} must be rejected");
        }
    }
}
