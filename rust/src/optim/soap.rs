//! SOAP — ShampoO with Adam in the Preconditioner's eigenbasis
//! (paper Algorithm 3), with the Algorithm 4 QR power-iteration refresh and
//! the §7 variants (one-sided, factorized, both).
//!
//! Per step for a `m×n` layer:
//! ```text
//!   M  ← β₁M + (1−β₁)G                 (original space)
//!   G' = Q_Lᵀ G Q_R,  M' = Q_Lᵀ M Q_R   (rotate)
//!   V  ← β₂V + (1−β₂) G'⊙G'            (rotated space, updated EVERY step)
//!   N' = M̂'/(√V̂ + ε)                   (Adam in the eigenbasis)
//!   N  = Q_L N' Q_Rᵀ                    (rotate back)
//!   W  ← W − ηN − η·wd·W
//!   L  ← β_s L + (1−β_s) GGᵀ,  R  ← β_s R + (1−β_s) GᵀG
//!   if t ≡ 0 (mod f):  Q_L ← QR(L·Q_L).Q,  Q_R ← QR(R·Q_R).Q   (Alg 4)
//! ```
//! The first step initializes `Q` by full (Jacobi) eigendecomposition, as in
//! the official implementation; subsequent refreshes use one power-iteration
//! step + QR, which is what keeps SOAP robust at large `f` (Fig 1 right):
//! the Adam second moment `V` keeps adapting every step in the slowly
//! rotating basis, while Shampoo's preconditioner is simply stale.

use std::time::Instant;

use super::adafactor::factored_normalize;
use super::hyper::{Hyper, RefreshMethod};
use super::LayerOptimizer;
use crate::linalg::{eigh, power_iter_refresh, Matrix};

pub struct Soap {
    h: Hyper,
    /// Momentum, kept in the ORIGINAL space (unlike GaLore — see §3).
    m: Matrix,
    /// Kronecker-factor EMAs.
    l: Option<Matrix>,
    r: Option<Matrix>,
    /// Eigenbasis estimates (columns = eigenvectors).
    ql: Option<Matrix>,
    qr: Option<Matrix>,
    /// Adam second moment in the ROTATED space (full) — `None` when
    /// `factorized` (then `va`/`vc` hold the Adafactor-style row/col EMAs).
    v: Option<Matrix>,
    va: Vec<f32>,
    vc: Vec<f32>,
    initialized: bool,
    refresh_secs: f64,
}

impl Soap {
    pub fn new(rows: usize, cols: usize, h: Hyper) -> Self {
        // §7.1 one-sided: rotate only the smaller side. Implementation
        // detail 3: dims over max_precond_dim keep Q = I.
        let mut left = rows <= h.max_precond_dim;
        let mut right = cols <= h.max_precond_dim;
        if h.one_sided {
            if rows <= cols {
                right = false;
            } else {
                left = false;
            }
        }
        let factorized = h.factorized;
        Self {
            m: Matrix::zeros(rows, cols),
            l: left.then(|| Matrix::zeros(rows, rows)),
            r: right.then(|| Matrix::zeros(cols, cols)),
            ql: None,
            qr: None,
            v: (!factorized).then(|| Matrix::zeros(rows, cols)),
            va: if factorized { vec![0.0; rows] } else { Vec::new() },
            vc: if factorized { vec![0.0; cols] } else { Vec::new() },
            initialized: false,
            refresh_secs: 0.0,
            h,
        }
    }

    /// Rotate into the eigenbasis: `Q_Lᵀ · X · Q_R` (identity sides skipped).
    fn project(&self, x: &Matrix) -> Matrix {
        let mut y = match &self.ql {
            Some(ql) => ql.matmul_tn(x),
            None => x.clone(),
        };
        if let Some(qr) = &self.qr {
            y = y.matmul(qr);
        }
        y
    }

    /// Rotate back: `Q_L · X · Q_Rᵀ`.
    fn project_back(&self, x: &Matrix) -> Matrix {
        let mut y = match &self.ql {
            Some(ql) => ql.matmul(x),
            None => x.clone(),
        };
        if let Some(qr) = &self.qr {
            y = y.matmul_nt(qr);
        }
        y
    }

    /// First-step initialization: set L/R from the first gradient and take a
    /// full eigendecomposition for the starting basis.
    fn init_basis(&mut self, g: &Matrix) {
        let t0 = Instant::now();
        if let Some(l) = &mut self.l {
            *l = g.matmul_nt(g);
            let (_, v) = eigh(l);
            self.ql = Some(v);
        }
        if let Some(r) = &mut self.r {
            *r = g.matmul_tn(g);
            let (_, v) = eigh(r);
            self.qr = Some(v);
        }
        self.initialized = true;
        self.refresh_secs += t0.elapsed().as_secs_f64();
    }

    /// Periodic eigenbasis refresh (Algorithm 4, or full eigh for the
    /// Fig 7-right ablation).
    fn refresh_basis(&mut self) {
        let t0 = Instant::now();
        match self.h.refresh {
            RefreshMethod::QrPowerIteration => {
                if let (Some(l), Some(ql)) = (&self.l, &self.ql) {
                    self.ql = Some(power_iter_refresh(l, ql));
                }
                if let (Some(r), Some(qr)) = (&self.r, &self.qr) {
                    self.qr = Some(power_iter_refresh(r, qr));
                }
            }
            RefreshMethod::Eigh => {
                // Warm-start from the current basis (§Perf): the EMA'd
                // factors drift slowly between refreshes, so the previous
                // eigenvectors are an excellent initial guess.
                if let Some(l) = &self.l {
                    let (_, v) = match &self.ql {
                        Some(prev) => crate::linalg::eigh_warm(l, prev),
                        None => eigh(l),
                    };
                    self.ql = Some(v);
                }
                if let Some(r) = &self.r {
                    let (_, v) = match &self.qr {
                        Some(prev) => crate::linalg::eigh_warm(r, prev),
                        None => eigh(r),
                    };
                    self.qr = Some(v);
                }
            }
        }
        self.refresh_secs += t0.elapsed().as_secs_f64();
    }
}

impl LayerOptimizer for Soap {
    fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
        let h = self.h.clone();
        if !self.initialized {
            self.init_basis(g);
        }

        // Momentum in the original space, then rotate both G and M.
        self.m.ema_inplace(g, h.beta1);
        let g_rot = self.project(g);
        let m_rot = self.project(&self.m);

        let bc1 = 1.0 - h.beta1.powi(t as i32);
        let bc2 = 1.0 - h.beta2.powi(t as i32);
        let m_hat = m_rot.scale(1.0 / bc1);

        // Adam (or Adafactor) second moment in the rotated space — updated
        // every step: this is the paper's fix for Shampoo's staleness.
        let n_rot = if let Some(v) = &mut self.v {
            let g2 = g_rot.hadamard(&g_rot);
            v.ema_inplace(&g2, h.beta2);
            m_hat.zip(v, |mi, vi| mi / ((vi / bc2).max(0.0).sqrt() + h.eps))
        } else {
            // Factorized (§7.2.1): Adafactor-style rank-1 V in the eigenbasis
            // — exactly the configuration Claim 1 equates with Shampoo.
            let g2 = g_rot.hadamard(&g_rot);
            let rows = g2.row_sums();
            let cols = g2.col_sums();
            for (ai, ri) in self.va.iter_mut().zip(&rows) {
                *ai = h.beta2 * *ai + (1.0 - h.beta2) * ri;
            }
            for (ci, cj) in self.vc.iter_mut().zip(&cols) {
                *ci = h.beta2 * *ci + (1.0 - h.beta2) * cj;
            }
            let a_hat: Vec<f32> = self.va.iter().map(|&x| x / bc2).collect();
            let c_hat: Vec<f32> = self.vc.iter().map(|&x| x / bc2).collect();
            factored_normalize(&m_hat, &a_hat, &c_hat, h.eps)
        };

        // Rotate back and apply.
        let n = self.project_back(&n_rot);
        w.axpy_inplace(-lr, &n);
        if h.weight_decay != 0.0 {
            w.scale_inplace(1.0 - lr * h.weight_decay);
        }

        // Factor EMAs + periodic basis refresh (after the step, per Alg 3).
        if let Some(l) = &mut self.l {
            let ggt = g.matmul_nt(g);
            l.ema_inplace(&ggt, h.shampoo_beta);
        }
        if let Some(r) = &mut self.r {
            let gtg = g.matmul_tn(g);
            r.ema_inplace(&gtg, h.shampoo_beta);
        }
        if t % h.precond_freq == 0 {
            self.refresh_basis();
        }
    }

    fn state_bytes(&self) -> usize {
        let mats = [
            self.l.as_ref().map(|x| x.numel()).unwrap_or(0),
            self.r.as_ref().map(|x| x.numel()).unwrap_or(0),
            self.ql.as_ref().map(|x| x.numel()).unwrap_or(0),
            self.qr.as_ref().map(|x| x.numel()).unwrap_or(0),
            self.v.as_ref().map(|x| x.numel()).unwrap_or(0),
            self.m.numel(),
            self.va.len(),
            self.vc.len(),
        ];
        mats.iter().sum::<usize>() * 4
    }

    fn name(&self) -> &'static str {
        "soap"
    }

    fn refresh_seconds(&self) -> f64 {
        self.refresh_secs
    }

    fn export_state(&self) -> Vec<Matrix> {
        // Layout: [flags(1×4), M, then present-only: L, R, QL, QR, V, va, vc]
        let flags = Matrix::from_vec(
            1,
            4,
            vec![
                self.initialized as u8 as f32,
                self.l.is_some() as u8 as f32,
                self.r.is_some() as u8 as f32,
                self.v.is_some() as u8 as f32,
            ],
        );
        let mut out = vec![flags, self.m.clone()];
        for opt in [&self.l, &self.r, &self.ql, &self.qr, &self.v] {
            if let Some(x) = opt {
                out.push(x.clone());
            }
        }
        if !self.va.is_empty() {
            out.push(Matrix::from_vec(1, self.va.len(), self.va.clone()));
            out.push(Matrix::from_vec(1, self.vc.len(), self.vc.clone()));
        }
        out
    }

    fn import_state(&mut self, state: Vec<Matrix>) -> anyhow::Result<()> {
        let mut it = state.into_iter();
        let flags = it.next().ok_or_else(|| anyhow::anyhow!("soap state empty"))?;
        anyhow::ensure!(flags.cols == 4, "soap state flags malformed");
        self.initialized = flags.data[0] != 0.0;
        let has_l = flags.data[1] != 0.0;
        let has_r = flags.data[2] != 0.0;
        let has_v = flags.data[3] != 0.0;
        self.m = it.next().ok_or_else(|| anyhow::anyhow!("soap state missing m"))?;
        let mut next = |what: &str| {
            it.next().ok_or_else(|| anyhow::anyhow!("soap state missing {what}"))
        };
        self.l = if has_l { Some(next("l")?) } else { None };
        self.r = if has_r { Some(next("r")?) } else { None };
        if self.initialized {
            self.ql = if has_l { Some(next("ql")?) } else { None };
            self.qr = if has_r { Some(next("qr")?) } else { None };
        }
        if has_v {
            self.v = Some(next("v")?);
        } else {
            let va = next("va")?;
            let vc = next("vc")?;
            self.va = va.data;
            self.vc = vc.data;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::adamw::AdamW;
    use crate::util::rng::Rng;

    fn h_base() -> Hyper {
        Hyper { weight_decay: 0.0, precond_freq: 5, ..Hyper::default() }
    }

    #[test]
    fn minimizes_quadratic() {
        let mut rng = Rng::new(40);
        let target = Matrix::randn(&mut rng, 6, 4, 1.0);
        let mut w = Matrix::zeros(6, 4);
        let mut opt = Soap::new(6, 4, h_base());
        for t in 1..=1500 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.1, "{}", w.max_abs_diff(&target));
    }

    #[test]
    fn identity_basis_equals_adamw_exactly() {
        // Paper: "if we fix both Q_L and Q_R to be identity … we would
        // recover Adam." Force identity via max_precond_dim = 0.
        let h = Hyper { max_precond_dim: 0, weight_decay: 0.0, ..Hyper::default() };
        let mut soap = Soap::new(5, 7, h.clone());
        let mut adam = AdamW::new(5, 7, h);
        let mut ws = Matrix::zeros(5, 7);
        let mut wa = Matrix::zeros(5, 7);
        let mut rng = Rng::new(41);
        for t in 1..=30 {
            let g = Matrix::randn(&mut rng, 5, 7, 1.0);
            soap.update(&mut ws, &g, t, 0.01);
            adam.update(&mut wa, &g, t, 0.01);
        }
        assert!(
            ws.max_abs_diff(&wa) < 2e-5,
            "SOAP(Q=I) diverged from AdamW by {}",
            ws.max_abs_diff(&wa)
        );
    }

    #[test]
    fn basis_stays_orthogonal_across_refreshes() {
        let mut rng = Rng::new(42);
        let mut opt = Soap::new(8, 8, h_base());
        let mut w = Matrix::zeros(8, 8);
        for t in 1..=50 {
            let g = Matrix::randn(&mut rng, 8, 8, 1.0);
            opt.update(&mut w, &g, t, 0.01);
        }
        let ql = opt.ql.as_ref().unwrap();
        let qtq = ql.matmul_tn(ql);
        assert!(qtq.max_abs_diff(&Matrix::eye(8)) < 1e-3);
    }

    #[test]
    fn one_sided_rotates_small_side_only() {
        let h = Hyper { one_sided: true, ..h_base() };
        let opt_wide = Soap::new(4, 16, h.clone()); // m < n: rotate left only
        assert!(opt_wide.l.is_some() && opt_wide.r.is_none());
        let opt_tall = Soap::new(16, 4, h); // m > n: rotate right only
        assert!(opt_tall.l.is_none() && opt_tall.r.is_some());
    }

    #[test]
    fn one_sided_still_minimizes() {
        let h = Hyper { one_sided: true, ..h_base() };
        let mut rng = Rng::new(43);
        let target = Matrix::randn(&mut rng, 4, 8, 1.0);
        let mut w = Matrix::zeros(4, 8);
        let mut opt = Soap::new(4, 8, h);
        for t in 1..=1500 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.15);
    }

    #[test]
    fn factorized_still_minimizes() {
        let h = Hyper { factorized: true, ..h_base() };
        let mut rng = Rng::new(44);
        let target = Matrix::randn(&mut rng, 5, 5, 1.0);
        let mut w = Matrix::zeros(5, 5);
        let mut opt = Soap::new(5, 5, h);
        for t in 1..=2000 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.15);
    }

    #[test]
    fn space_usage_formulas_section_7_2() {
        // Full SOAP on m×n, m,n both preconditioned:
        // 2m² (L,Q_L) + 2n² (R,Q_R) + 2mn (M,V) held here (the paper's 3mn
        // includes the gradient, which the optimizer does not own).
        let (m, n) = (8usize, 6usize);
        let full = Soap::new(m, n, Hyper { weight_decay: 0.0, ..Hyper::default() });
        // ql/qr are allocated on first update; count post-init.
        let mut w = Matrix::zeros(m, n);
        let mut full = {
            let mut rng = Rng::new(45);
            let g = Matrix::randn(&mut rng, m, n, 1.0);
            let mut o = full;
            o.update(&mut w, &g, 1, 0.0);
            o
        };
        let _ = &mut full;
        assert_eq!(full.state_bytes(), (2 * m * m + 2 * n * n + 2 * m * n) * 4);

        // One-sided + factorized: 2·min(m,n)² + mn + m + n.
        let h = Hyper { one_sided: true, factorized: true, ..Hyper::default() };
        let mut o = Soap::new(m, n, h);
        let mut rng = Rng::new(46);
        let g = Matrix::randn(&mut rng, m, n, 1.0);
        o.update(&mut w, &g, 1, 0.0);
        assert_eq!(o.state_bytes(), (2 * n * n + m * n + m + n) * 4);
    }

    #[test]
    fn v_adapts_between_refreshes_unlike_shampoo() {
        // The core SOAP property: second moment changes on every step even
        // with a huge preconditioning frequency.
        let h = Hyper { precond_freq: 1000, weight_decay: 0.0, ..Hyper::default() };
        let mut opt = Soap::new(4, 4, h);
        let mut rng = Rng::new(47);
        let mut w = Matrix::zeros(4, 4);
        let g = Matrix::randn(&mut rng, 4, 4, 1.0);
        opt.update(&mut w, &g, 1, 0.01);
        let v1 = opt.v.as_ref().unwrap().clone();
        let g = Matrix::randn(&mut rng, 4, 4, 1.0);
        opt.update(&mut w, &g, 2, 0.01);
        let v2 = opt.v.as_ref().unwrap().clone();
        assert!(v1.max_abs_diff(&v2) > 0.0);
    }
}
