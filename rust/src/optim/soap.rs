//! SOAP — ShampoO with Adam in the Preconditioner's eigenbasis
//! (paper Algorithm 3), as a named preset over the composable core:
//!
//! ```text
//!   SOAP            = EigenBasis(rotation) × Adam       (momentum rotated)
//!   factorized SOAP = EigenBasis(rotation) × Adafactor  (§7.2.1)
//! ```
//!
//! The basis ([`crate::optim::compose::EigenBasis`], rotation flavor) owns the
//! Kronecker-factor EMAs, the first-step full eigendecomposition, and the
//! Algorithm 4 QR power-iteration refresh (inline or async); the engine
//! ([`crate::optim::compose::AdamEngine`] with momentum in the ORIGINAL space — the §3
//! difference from GaLore) runs Adam in the rotated coordinates, updating
//! its second moment EVERY step. That per-step adaptivity in a slowly
//! rotating basis is what keeps SOAP robust at large `f` (Fig 1 right):
//! Shampoo's preconditioner is simply stale between refreshes.
//!
//! The composition is bitwise-identical to the pre-refactor monolithic
//! implementation (`rust/tests/golden_compose.rs`).

use super::compose::{presets, DynComposed};
use super::hyper::Hyper;

/// Named preset: [`Soap::new`] builds the eigenbasis × Adam (or × Adafactor
/// when `h.factorized`) composition.
pub struct Soap;

impl Soap {
    // Historical constructor name, kept across the compose refactor; it
    // intentionally returns the composed type, not Self.
    #[allow(clippy::new_ret_no_self)]
    pub fn new(rows: usize, cols: usize, h: Hyper) -> DynComposed {
        presets::soap(rows, cols, h)
    }
}

// Re-exported so existing code keeps one import site for the composed type.
pub use super::compose::EigenBasis;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::optim::adamw::AdamW;
    use crate::optim::LayerOptimizer;
    use crate::precond::RefreshService;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn h_base() -> Hyper {
        Hyper { weight_decay: 0.0, precond_freq: 5, ..Hyper::default() }
    }

    fn eigen(opt: &DynComposed) -> &EigenBasis {
        opt.basis.as_eigen().expect("soap preset uses the eigenbasis")
    }

    #[test]
    fn minimizes_quadratic() {
        let mut rng = Rng::new(40);
        let target = Matrix::randn(&mut rng, 6, 4, 1.0);
        let mut w = Matrix::zeros(6, 4);
        let mut opt = Soap::new(6, 4, h_base());
        for t in 1..=1500 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.1, "{}", w.max_abs_diff(&target));
    }

    #[test]
    fn identity_basis_equals_adamw_exactly() {
        // Paper: "if we fix both Q_L and Q_R to be identity … we would
        // recover Adam." Force identity via max_precond_dim = 0.
        let h = Hyper { max_precond_dim: 0, weight_decay: 0.0, ..Hyper::default() };
        let mut soap = Soap::new(5, 7, h.clone());
        let mut adam = AdamW::new(5, 7, h);
        let mut ws = Matrix::zeros(5, 7);
        let mut wa = Matrix::zeros(5, 7);
        let mut rng = Rng::new(41);
        for t in 1..=30 {
            let g = Matrix::randn(&mut rng, 5, 7, 1.0);
            soap.update(&mut ws, &g, t, 0.01);
            adam.update(&mut wa, &g, t, 0.01);
        }
        assert!(
            ws.max_abs_diff(&wa) < 2e-5,
            "SOAP(Q=I) diverged from AdamW by {}",
            ws.max_abs_diff(&wa)
        );
    }

    #[test]
    fn basis_stays_orthogonal_across_refreshes() {
        let mut rng = Rng::new(42);
        let mut opt = Soap::new(8, 8, h_base());
        let mut w = Matrix::zeros(8, 8);
        for t in 1..=50 {
            let g = Matrix::randn(&mut rng, 8, 8, 1.0);
            opt.update(&mut w, &g, t, 0.01);
        }
        let ql = eigen(&opt).left_q.as_ref().unwrap();
        let qtq = ql.matmul_tn(ql);
        assert!(qtq.max_abs_diff(&Matrix::eye(8)) < 1e-3);
    }

    #[test]
    fn one_sided_rotates_small_side_only() {
        let h = Hyper { one_sided: true, ..h_base() };
        let opt_wide = Soap::new(4, 16, h.clone()); // m < n: rotate left only
        assert!(eigen(&opt_wide).l.is_some() && eigen(&opt_wide).r.is_none());
        let opt_tall = Soap::new(16, 4, h); // m > n: rotate right only
        assert!(eigen(&opt_tall).l.is_none() && eigen(&opt_tall).r.is_some());
    }

    #[test]
    fn one_sided_still_minimizes() {
        let h = Hyper { one_sided: true, ..h_base() };
        let mut rng = Rng::new(43);
        let target = Matrix::randn(&mut rng, 4, 8, 1.0);
        let mut w = Matrix::zeros(4, 8);
        let mut opt = Soap::new(4, 8, h);
        for t in 1..=1500 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.15);
    }

    #[test]
    fn factorized_still_minimizes() {
        let h = Hyper { factorized: true, ..h_base() };
        let mut rng = Rng::new(44);
        let target = Matrix::randn(&mut rng, 5, 5, 1.0);
        let mut w = Matrix::zeros(5, 5);
        let mut opt = Soap::new(5, 5, h);
        for t in 1..=2000 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.15);
    }

    #[test]
    fn space_usage_formulas_section_7_2() {
        // Full SOAP on m×n, m,n both preconditioned:
        // 2m² (L,Q_L) + 2n² (R,Q_R) + 2mn (M,V) held here (the paper's 3mn
        // includes the gradient, which the optimizer does not own). The
        // per-buffer byte widths route through the state dtype: L/R and V
        // follow `state_dtype.bytes()`, while M and the eigenbases stay f32.
        use crate::optim::hyper::StateDtype;
        let (m, n) = (8usize, 6usize);
        let count = |h: Hyper, seed: u64| -> usize {
            let mut w = Matrix::zeros(m, n);
            let mut rng = Rng::new(seed);
            let g = Matrix::randn(&mut rng, m, n, 1.0);
            let mut o = Soap::new(m, n, h);
            // ql/qr are allocated on first update; count post-init.
            o.update(&mut w, &g, 1, 0.0);
            o.state_bytes()
        };
        for dtype in [StateDtype::F32, StateDtype::Bf16] {
            let b = dtype.bytes();
            let h = Hyper { weight_decay: 0.0, state_dtype: dtype, ..Hyper::default() };
            assert_eq!(
                count(h, 45),
                (m * m + n * n + m * n) * b + (m * m + n * n + m * n) * 4,
                "full SOAP accounting wrong under {}",
                dtype.name()
            );

            // One-sided + factorized: L + Q_L at min(m,n)², M at mn f32,
            // A + C at m + n in the state dtype.
            let h = Hyper {
                one_sided: true,
                factorized: true,
                state_dtype: dtype,
                ..Hyper::default()
            };
            assert_eq!(
                count(h, 46),
                (n * n + m + n) * b + (n * n + m * n) * 4,
                "factorized accounting wrong under {}",
                dtype.name()
            );
        }
        // The headline claim: bf16 halves the dtype-routed share exactly.
        let h32 = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let h16 =
            Hyper { weight_decay: 0.0, state_dtype: StateDtype::Bf16, ..Hyper::default() };
        let (f32_bytes, bf16_bytes) = (count(h32, 45), count(h16, 45));
        let fixed = (m * m + n * n + m * n) * 4; // Q_L, Q_R, M stay f32
        assert_eq!(bf16_bytes - fixed, (f32_bytes - fixed) / 2);
    }

    #[test]
    fn async_mode_adopts_published_basis_and_stays_orthonormal() {
        // Drive the async path deterministically: drain the service after
        // each step so every refresh publishes before the next step adopts.
        let svc = Arc::new(RefreshService::new(1));
        let mut opt = Soap::new(8, 8, h_base()); // f = 5
        assert!(opt.attach_async(&svc));
        let mut rng = Rng::new(48);
        let mut w = Matrix::zeros(8, 8);
        for t in 1..=23 {
            let g = Matrix::randn(&mut rng, 8, 8, 1.0);
            opt.update(&mut w, &g, t, 0.01);
            svc.wait_idle();
        }
        // Refresh steps at t = 5, 10, 15, 20 ⇒ 4 publications, all adopted.
        assert_eq!(svc.stats().completed, 4);
        assert_eq!(eigen(&opt).adopted_version, 4);
        assert_eq!(opt.basis_snapshot_step(), Some(20));
        let ql = eigen(&opt).left_q.as_ref().unwrap();
        let qtq = ql.matmul_tn(ql);
        assert!(
            qtq.max_abs_diff(&Matrix::eye(8)) < 1e-3,
            "async-adopted basis not orthonormal: {}",
            qtq.max_abs_diff(&Matrix::eye(8))
        );
        // Background work must NOT appear in the hot-path refresh account.
        let inline_share = opt.refresh_seconds();
        assert!(svc.refresh_seconds() > 0.0);
        // Only the first-step eigh init runs inline in async mode.
        assert!(inline_share < svc.refresh_seconds() + 1.0);
        assert!(w.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn async_mode_minimizes_quadratic_like_inline() {
        let svc = Arc::new(RefreshService::new(1));
        let mut rng = Rng::new(49);
        let target = Matrix::randn(&mut rng, 6, 4, 1.0);

        let run = |mut opt: DynComposed, drain: Option<&RefreshService>| -> Matrix {
            let mut w = Matrix::zeros(6, 4);
            for t in 1..=1500 {
                let g = w.sub(&target).scale(2.0);
                opt.update(&mut w, &g, t, 0.02);
                if let Some(s) = drain {
                    s.wait_idle();
                }
            }
            w
        };
        let w_inline = run(Soap::new(6, 4, h_base()), None);
        let mut async_opt = Soap::new(6, 4, h_base());
        assert!(async_opt.attach_async(&svc));
        let w_async = run(async_opt, Some(&*svc));

        // Both converge; the delayed basis costs at most a whisker.
        assert!(w_inline.max_abs_diff(&target) < 0.1);
        assert!(
            w_async.max_abs_diff(&target) < 0.12,
            "async SOAP failed to converge: {}",
            w_async.max_abs_diff(&target)
        );
    }

    #[test]
    fn attach_async_refuses_identity_only_layers() {
        let svc = Arc::new(RefreshService::new(1));
        let h = Hyper { max_precond_dim: 0, ..Hyper::default() };
        let mut opt = Soap::new(5, 7, h);
        assert!(!opt.attach_async(&svc), "nothing to refresh ⇒ stay inline");
        assert_eq!(opt.basis_snapshot_step(), None);
    }

    #[test]
    fn inline_refresh_phase_staggers_the_schedule() {
        // φ = 2, f = 5 ⇒ refreshes at t = 2, 7, 12 … Verify via basis_step.
        let h = Hyper { refresh_phase: 2, ..h_base() };
        let mut opt = Soap::new(4, 4, h);
        let mut rng = Rng::new(50);
        let mut w = Matrix::zeros(4, 4);
        for t in 1..=8 {
            let g = Matrix::randn(&mut rng, 4, 4, 1.0);
            opt.update(&mut w, &g, t, 0.01);
            let expect = match t {
                1 => 1, // init
                2..=6 => 2,
                _ => 7,
            };
            assert_eq!(opt.basis_snapshot_step(), Some(expect), "at t={t}");
        }
    }

    #[test]
    fn v_adapts_between_refreshes_unlike_shampoo() {
        // The core SOAP property: second moment changes on every step even
        // with a huge preconditioning frequency.
        let h = Hyper { precond_freq: 1000, weight_decay: 0.0, ..Hyper::default() };
        let mut opt = Soap::new(4, 4, h);
        let mut rng = Rng::new(47);
        let mut w = Matrix::zeros(4, 4);
        let g = Matrix::randn(&mut rng, 4, 4, 1.0);
        opt.update(&mut w, &g, 1, 0.01);
        let v1 = opt.engine.as_adam().unwrap().v.to_matrix();
        let g = Matrix::randn(&mut rng, 4, 4, 1.0);
        opt.update(&mut w, &g, 2, 0.01);
        let v2 = opt.engine.as_adam().unwrap().v.to_matrix();
        assert!(v1.max_abs_diff(&v2) > 0.0);
    }
}
