//! SOAP — ShampoO with Adam in the Preconditioner's eigenbasis
//! (paper Algorithm 3), with the Algorithm 4 QR power-iteration refresh and
//! the §7 variants (one-sided, factorized, both).
//!
//! Per step for a `m×n` layer:
//! ```text
//!   M  ← β₁M + (1−β₁)G                 (original space)
//!   G' = Q_Lᵀ G Q_R,  M' = Q_Lᵀ M Q_R   (rotate)
//!   V  ← β₂V + (1−β₂) G'⊙G'            (rotated space, updated EVERY step)
//!   N' = M̂'/(√V̂ + ε)                   (Adam in the eigenbasis)
//!   N  = Q_L N' Q_Rᵀ                    (rotate back)
//!   W  ← W − ηN − η·wd·W
//!   L  ← β_s L + (1−β_s) GGᵀ,  R  ← β_s R + (1−β_s) GᵀG
//!   if t ≡ 0 (mod f):  Q_L ← QR(L·Q_L).Q,  Q_R ← QR(R·Q_R).Q   (Alg 4)
//! ```
//! The first step initializes `Q` by full (Jacobi) eigendecomposition, as in
//! the official implementation; subsequent refreshes use one power-iteration
//! step + QR, which is what keeps SOAP robust at large `f` (Fig 1 right):
//! the Adam second moment `V` keeps adapting every step in the slowly
//! rotating basis, while Shampoo's preconditioner is simply stale.

use std::sync::Arc;
use std::time::Instant;

use super::adafactor::factored_normalize;
use super::hyper::{Hyper, RefreshMethod};
use super::LayerOptimizer;
use crate::linalg::{eigh, power_iter_refresh, Matrix};
use crate::precond::{BasisHandle, BasisPayload, RefreshService};

pub struct Soap {
    h: Hyper,
    /// Momentum, kept in the ORIGINAL space (unlike GaLore — see §3).
    m: Matrix,
    /// Kronecker-factor EMAs.
    l: Option<Matrix>,
    r: Option<Matrix>,
    /// Eigenbasis estimates (columns = eigenvectors).
    ql: Option<Matrix>,
    qr: Option<Matrix>,
    /// Adam second moment in the ROTATED space (full) — `None` when
    /// `factorized` (then `va`/`vc` hold the Adafactor-style row/col EMAs).
    v: Option<Matrix>,
    va: Vec<f32>,
    vc: Vec<f32>,
    initialized: bool,
    refresh_secs: f64,
    /// Async refresh plumbing (`None` ⇒ inline refreshes). The handle is this
    /// layer's private mailbox; the service is shared across layers.
    service: Option<Arc<RefreshService>>,
    handle: Option<Arc<BasisHandle>>,
    /// Version of the last publication adopted into `ql`/`qr`.
    adopted_version: u64,
    /// Step whose factors back the ACTIVE basis (staleness = t − this).
    basis_step: u64,
}

impl Soap {
    pub fn new(rows: usize, cols: usize, h: Hyper) -> Self {
        // §7.1 one-sided: rotate only the smaller side. Implementation
        // detail 3: dims over max_precond_dim keep Q = I.
        let mut left = rows <= h.max_precond_dim;
        let mut right = cols <= h.max_precond_dim;
        if h.one_sided {
            if rows <= cols {
                right = false;
            } else {
                left = false;
            }
        }
        let factorized = h.factorized;
        Self {
            m: Matrix::zeros(rows, cols),
            l: left.then(|| Matrix::zeros(rows, rows)),
            r: right.then(|| Matrix::zeros(cols, cols)),
            ql: None,
            qr: None,
            v: (!factorized).then(|| Matrix::zeros(rows, cols)),
            va: if factorized { vec![0.0; rows] } else { Vec::new() },
            vc: if factorized { vec![0.0; cols] } else { Vec::new() },
            initialized: false,
            refresh_secs: 0.0,
            service: None,
            handle: None,
            adopted_version: 0,
            basis_step: 0,
            h,
        }
    }

    /// Rotate into the eigenbasis: `Q_Lᵀ · X · Q_R` (identity sides skipped).
    fn project(&self, x: &Matrix) -> Matrix {
        let mut y = match &self.ql {
            Some(ql) => ql.matmul_tn(x),
            None => x.clone(),
        };
        if let Some(qr) = &self.qr {
            y = y.matmul(qr);
        }
        y
    }

    /// Rotate back: `Q_L · X · Q_Rᵀ`.
    fn project_back(&self, x: &Matrix) -> Matrix {
        let mut y = match &self.ql {
            Some(ql) => ql.matmul(x),
            None => x.clone(),
        };
        if let Some(qr) = &self.qr {
            y = y.matmul_nt(qr);
        }
        y
    }

    /// First-step initialization: set L/R from the first gradient and take a
    /// full eigendecomposition for the starting basis.
    fn init_basis(&mut self, g: &Matrix) {
        let t0 = Instant::now();
        if let Some(l) = &mut self.l {
            *l = g.matmul_nt(g);
            let (_, v) = eigh(l);
            self.ql = Some(v);
        }
        if let Some(r) = &mut self.r {
            *r = g.matmul_tn(g);
            let (_, v) = eigh(r);
            self.qr = Some(v);
        }
        self.initialized = true;
        self.refresh_secs += t0.elapsed().as_secs_f64();
    }

    /// The refresh math (Algorithm 4 power-iteration + QR, or warm `eigh`
    /// for the Fig 7-right ablation), as a pure function of factor/basis
    /// snapshots so the inline and background paths run IDENTICAL code.
    fn compute_refresh(
        method: RefreshMethod,
        l: Option<&Matrix>,
        r: Option<&Matrix>,
        ql: Option<&Matrix>,
        qr: Option<&Matrix>,
    ) -> (Option<Matrix>, Option<Matrix>) {
        let one_side = |p: Option<&Matrix>, q: Option<&Matrix>| -> Option<Matrix> {
            match method {
                RefreshMethod::QrPowerIteration => match (p, q) {
                    (Some(p), Some(q)) => Some(power_iter_refresh(p, q)),
                    _ => None,
                },
                // Warm-start from the current basis (§Perf): the EMA'd
                // factors drift slowly between refreshes, so the previous
                // eigenvectors are an excellent initial guess.
                RefreshMethod::Eigh => p.map(|p| {
                    match q {
                        Some(prev) => crate::linalg::eigh_warm(p, prev).1,
                        None => eigh(p).1,
                    }
                }),
            }
        };
        (one_side(l, ql), one_side(r, qr))
    }

    /// Periodic eigenbasis refresh, executed inline (synchronously).
    fn refresh_basis(&mut self, t: u64) {
        let t0 = Instant::now();
        let (new_ql, new_qr) = Self::compute_refresh(
            self.h.refresh,
            self.l.as_ref(),
            self.r.as_ref(),
            self.ql.as_ref(),
            self.qr.as_ref(),
        );
        if let Some(q) = new_ql {
            self.ql = Some(q);
        }
        if let Some(q) = new_qr {
            self.qr = Some(q);
        }
        self.basis_step = t;
        self.refresh_secs += t0.elapsed().as_secs_f64();
    }

    /// Async mode: swap in the newest published basis, if any. One atomic
    /// load on the no-news path; the payload pair is adopted wholesale, so a
    /// torn basis is impossible (see `precond::handle`).
    fn adopt_published(&mut self) {
        let Some(handle) = &self.handle else { return };
        if handle.version() <= self.adopted_version {
            return;
        }
        if let Some(published) = handle.latest() {
            if published.version > self.adopted_version {
                if let Some(q) = &published.payload.left {
                    self.ql = Some(q.clone());
                }
                if let Some(q) = &published.payload.right {
                    self.qr = Some(q.clone());
                }
                self.adopted_version = published.version;
                self.basis_step = published.snapshot_step;
            }
        }
    }

    /// Async mode: snapshot the factor EMAs + current basis and hand the
    /// refresh to the service. Skipped (not queued) while a previous refresh
    /// is still in flight, so a slow decomposition sheds load instead of
    /// building a backlog.
    fn enqueue_refresh(&self, service: &Arc<RefreshService>, handle: &Arc<BasisHandle>, t: u64) {
        if !handle.try_begin_refresh() {
            return;
        }
        let method = self.h.refresh;
        let l = self.l.clone();
        let r = self.r.clone();
        let ql = self.ql.clone();
        let qr = self.qr.clone();
        service.enqueue(
            Arc::clone(handle),
            t,
            Box::new(move || {
                let (left, right) =
                    Self::compute_refresh(method, l.as_ref(), r.as_ref(), ql.as_ref(), qr.as_ref());
                BasisPayload { left, right, left_aux: None, right_aux: None }
            }),
        );
    }
}

impl LayerOptimizer for Soap {
    fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
        let h = self.h.clone();
        if !self.initialized {
            self.init_basis(g);
            self.basis_step = t;
        }
        // Async mode: pick up any basis the background service published
        // since the last step — before projecting, so it's used immediately.
        self.adopt_published();

        // Momentum in the original space, then rotate both G and M.
        self.m.ema_inplace(g, h.beta1);
        let g_rot = self.project(g);
        let m_rot = self.project(&self.m);

        let bc1 = 1.0 - h.beta1.powi(t as i32);
        let bc2 = 1.0 - h.beta2.powi(t as i32);
        let m_hat = m_rot.scale(1.0 / bc1);

        // Adam (or Adafactor) second moment in the rotated space — updated
        // every step: this is the paper's fix for Shampoo's staleness.
        let n_rot = if let Some(v) = &mut self.v {
            let g2 = g_rot.hadamard(&g_rot);
            v.ema_inplace(&g2, h.beta2);
            m_hat.zip(v, |mi, vi| mi / ((vi / bc2).max(0.0).sqrt() + h.eps))
        } else {
            // Factorized (§7.2.1): Adafactor-style rank-1 V in the eigenbasis
            // — exactly the configuration Claim 1 equates with Shampoo.
            let g2 = g_rot.hadamard(&g_rot);
            let rows = g2.row_sums();
            let cols = g2.col_sums();
            for (ai, ri) in self.va.iter_mut().zip(&rows) {
                *ai = h.beta2 * *ai + (1.0 - h.beta2) * ri;
            }
            for (ci, cj) in self.vc.iter_mut().zip(&cols) {
                *ci = h.beta2 * *ci + (1.0 - h.beta2) * cj;
            }
            let a_hat: Vec<f32> = self.va.iter().map(|&x| x / bc2).collect();
            let c_hat: Vec<f32> = self.vc.iter().map(|&x| x / bc2).collect();
            factored_normalize(&m_hat, &a_hat, &c_hat, h.eps)
        };

        // Rotate back and apply.
        let n = self.project_back(&n_rot);
        w.axpy_inplace(-lr, &n);
        if h.weight_decay != 0.0 {
            w.scale_inplace(1.0 - lr * h.weight_decay);
        }

        // Factor EMAs + periodic basis refresh (after the step, per Alg 3).
        if let Some(l) = &mut self.l {
            let ggt = g.matmul_nt(g);
            l.ema_inplace(&ggt, h.shampoo_beta);
        }
        if let Some(r) = &mut self.r {
            let gtg = g.matmul_tn(g);
            r.ema_inplace(&gtg, h.shampoo_beta);
        }
        if h.is_refresh_step(t) {
            match (self.service.clone(), self.handle.clone()) {
                (Some(service), Some(handle)) => self.enqueue_refresh(&service, &handle, t),
                _ => self.refresh_basis(t),
            }
        }
    }

    fn state_bytes(&self) -> usize {
        let mats = [
            self.l.as_ref().map(|x| x.numel()).unwrap_or(0),
            self.r.as_ref().map(|x| x.numel()).unwrap_or(0),
            self.ql.as_ref().map(|x| x.numel()).unwrap_or(0),
            self.qr.as_ref().map(|x| x.numel()).unwrap_or(0),
            self.v.as_ref().map(|x| x.numel()).unwrap_or(0),
            self.m.numel(),
            self.va.len(),
            self.vc.len(),
        ];
        mats.iter().sum::<usize>() * 4
    }

    fn name(&self) -> &'static str {
        "soap"
    }

    fn refresh_seconds(&self) -> f64 {
        self.refresh_secs
    }

    fn attach_async(&mut self, service: &Arc<RefreshService>) -> bool {
        if self.l.is_none() && self.r.is_none() {
            return false; // both sides identity ⇒ nothing to refresh
        }
        self.service = Some(Arc::clone(service));
        self.handle = Some(Arc::new(BasisHandle::new()));
        self.adopted_version = 0;
        true
    }

    fn basis_snapshot_step(&self) -> Option<u64> {
        (self.initialized && (self.ql.is_some() || self.qr.is_some()))
            .then_some(self.basis_step)
    }

    fn export_state(&self) -> Vec<Matrix> {
        // Layout: [flags(1×5), M, then present-only: L, R, QL, QR, V, va, vc]
        // flags[4] = basis_step, so staleness survives a checkpoint resume
        // (f32 is exact up to 2^24 steps — far beyond our runs).
        let flags = Matrix::from_vec(
            1,
            5,
            vec![
                self.initialized as u8 as f32,
                self.l.is_some() as u8 as f32,
                self.r.is_some() as u8 as f32,
                self.v.is_some() as u8 as f32,
                self.basis_step as f32,
            ],
        );
        let mut out = vec![flags, self.m.clone()];
        for opt in [&self.l, &self.r, &self.ql, &self.qr, &self.v] {
            if let Some(x) = opt {
                out.push(x.clone());
            }
        }
        if !self.va.is_empty() {
            out.push(Matrix::from_vec(1, self.va.len(), self.va.clone()));
            out.push(Matrix::from_vec(1, self.vc.len(), self.vc.clone()));
        }
        out
    }

    fn import_state(&mut self, state: Vec<Matrix>) -> anyhow::Result<()> {
        let mut it = state.into_iter();
        let flags = it.next().ok_or_else(|| anyhow::anyhow!("soap state empty"))?;
        // cols == 4 accepts pre-basis_step checkpoints (staleness restarts
        // from 0 after such a restore; the math is unaffected).
        anyhow::ensure!(flags.cols == 4 || flags.cols == 5, "soap state flags malformed");
        self.initialized = flags.data[0] != 0.0;
        let has_l = flags.data[1] != 0.0;
        let has_r = flags.data[2] != 0.0;
        let has_v = flags.data[3] != 0.0;
        self.basis_step = if flags.cols == 5 { flags.data[4] as u64 } else { 0 };
        // Refreshes enqueued before the restore were computed from discarded
        // factors; drain them, then skip every pre-restore publication.
        if let (Some(service), Some(handle)) = (&self.service, &self.handle) {
            service.wait_idle();
            self.adopted_version = handle.version();
        }
        self.m = it.next().ok_or_else(|| anyhow::anyhow!("soap state missing m"))?;
        let mut next = |what: &str| {
            it.next().ok_or_else(|| anyhow::anyhow!("soap state missing {what}"))
        };
        self.l = if has_l { Some(next("l")?) } else { None };
        self.r = if has_r { Some(next("r")?) } else { None };
        if self.initialized {
            self.ql = if has_l { Some(next("ql")?) } else { None };
            self.qr = if has_r { Some(next("qr")?) } else { None };
        }
        if has_v {
            self.v = Some(next("v")?);
        } else {
            let va = next("va")?;
            let vc = next("vc")?;
            self.va = va.data;
            self.vc = vc.data;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::adamw::AdamW;
    use crate::util::rng::Rng;

    fn h_base() -> Hyper {
        Hyper { weight_decay: 0.0, precond_freq: 5, ..Hyper::default() }
    }

    #[test]
    fn minimizes_quadratic() {
        let mut rng = Rng::new(40);
        let target = Matrix::randn(&mut rng, 6, 4, 1.0);
        let mut w = Matrix::zeros(6, 4);
        let mut opt = Soap::new(6, 4, h_base());
        for t in 1..=1500 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.1, "{}", w.max_abs_diff(&target));
    }

    #[test]
    fn identity_basis_equals_adamw_exactly() {
        // Paper: "if we fix both Q_L and Q_R to be identity … we would
        // recover Adam." Force identity via max_precond_dim = 0.
        let h = Hyper { max_precond_dim: 0, weight_decay: 0.0, ..Hyper::default() };
        let mut soap = Soap::new(5, 7, h.clone());
        let mut adam = AdamW::new(5, 7, h);
        let mut ws = Matrix::zeros(5, 7);
        let mut wa = Matrix::zeros(5, 7);
        let mut rng = Rng::new(41);
        for t in 1..=30 {
            let g = Matrix::randn(&mut rng, 5, 7, 1.0);
            soap.update(&mut ws, &g, t, 0.01);
            adam.update(&mut wa, &g, t, 0.01);
        }
        assert!(
            ws.max_abs_diff(&wa) < 2e-5,
            "SOAP(Q=I) diverged from AdamW by {}",
            ws.max_abs_diff(&wa)
        );
    }

    #[test]
    fn basis_stays_orthogonal_across_refreshes() {
        let mut rng = Rng::new(42);
        let mut opt = Soap::new(8, 8, h_base());
        let mut w = Matrix::zeros(8, 8);
        for t in 1..=50 {
            let g = Matrix::randn(&mut rng, 8, 8, 1.0);
            opt.update(&mut w, &g, t, 0.01);
        }
        let ql = opt.ql.as_ref().unwrap();
        let qtq = ql.matmul_tn(ql);
        assert!(qtq.max_abs_diff(&Matrix::eye(8)) < 1e-3);
    }

    #[test]
    fn one_sided_rotates_small_side_only() {
        let h = Hyper { one_sided: true, ..h_base() };
        let opt_wide = Soap::new(4, 16, h.clone()); // m < n: rotate left only
        assert!(opt_wide.l.is_some() && opt_wide.r.is_none());
        let opt_tall = Soap::new(16, 4, h); // m > n: rotate right only
        assert!(opt_tall.l.is_none() && opt_tall.r.is_some());
    }

    #[test]
    fn one_sided_still_minimizes() {
        let h = Hyper { one_sided: true, ..h_base() };
        let mut rng = Rng::new(43);
        let target = Matrix::randn(&mut rng, 4, 8, 1.0);
        let mut w = Matrix::zeros(4, 8);
        let mut opt = Soap::new(4, 8, h);
        for t in 1..=1500 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.15);
    }

    #[test]
    fn factorized_still_minimizes() {
        let h = Hyper { factorized: true, ..h_base() };
        let mut rng = Rng::new(44);
        let target = Matrix::randn(&mut rng, 5, 5, 1.0);
        let mut w = Matrix::zeros(5, 5);
        let mut opt = Soap::new(5, 5, h);
        for t in 1..=2000 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.15);
    }

    #[test]
    fn space_usage_formulas_section_7_2() {
        // Full SOAP on m×n, m,n both preconditioned:
        // 2m² (L,Q_L) + 2n² (R,Q_R) + 2mn (M,V) held here (the paper's 3mn
        // includes the gradient, which the optimizer does not own).
        let (m, n) = (8usize, 6usize);
        let full = Soap::new(m, n, Hyper { weight_decay: 0.0, ..Hyper::default() });
        // ql/qr are allocated on first update; count post-init.
        let mut w = Matrix::zeros(m, n);
        let mut full = {
            let mut rng = Rng::new(45);
            let g = Matrix::randn(&mut rng, m, n, 1.0);
            let mut o = full;
            o.update(&mut w, &g, 1, 0.0);
            o
        };
        let _ = &mut full;
        assert_eq!(full.state_bytes(), (2 * m * m + 2 * n * n + 2 * m * n) * 4);

        // One-sided + factorized: 2·min(m,n)² + mn + m + n.
        let h = Hyper { one_sided: true, factorized: true, ..Hyper::default() };
        let mut o = Soap::new(m, n, h);
        let mut rng = Rng::new(46);
        let g = Matrix::randn(&mut rng, m, n, 1.0);
        o.update(&mut w, &g, 1, 0.0);
        assert_eq!(o.state_bytes(), (2 * n * n + m * n + m + n) * 4);
    }

    #[test]
    fn async_mode_adopts_published_basis_and_stays_orthonormal() {
        // Drive the async path deterministically: drain the service after
        // each step so every refresh publishes before the next step adopts.
        let svc = Arc::new(RefreshService::new(1));
        let mut opt = Soap::new(8, 8, h_base()); // f = 5
        assert!(opt.attach_async(&svc));
        let mut rng = Rng::new(48);
        let mut w = Matrix::zeros(8, 8);
        for t in 1..=23 {
            let g = Matrix::randn(&mut rng, 8, 8, 1.0);
            opt.update(&mut w, &g, t, 0.01);
            svc.wait_idle();
        }
        // Refresh steps at t = 5, 10, 15, 20 ⇒ 4 publications, all adopted.
        assert_eq!(svc.stats().completed, 4);
        assert_eq!(opt.adopted_version, 4);
        assert_eq!(opt.basis_snapshot_step(), Some(20));
        let ql = opt.ql.as_ref().unwrap();
        let qtq = ql.matmul_tn(ql);
        assert!(
            qtq.max_abs_diff(&Matrix::eye(8)) < 1e-3,
            "async-adopted basis not orthonormal: {}",
            qtq.max_abs_diff(&Matrix::eye(8))
        );
        // Background work must NOT appear in the hot-path refresh account.
        let inline_share = opt.refresh_seconds();
        assert!(svc.refresh_seconds() > 0.0);
        // Only the first-step eigh init runs inline in async mode.
        assert!(inline_share < svc.refresh_seconds() + 1.0);
        assert!(w.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn async_mode_minimizes_quadratic_like_inline() {
        let svc = Arc::new(RefreshService::new(1));
        let mut rng = Rng::new(49);
        let target = Matrix::randn(&mut rng, 6, 4, 1.0);

        let run = |mut opt: Soap, drain: Option<&RefreshService>| -> Matrix {
            let mut w = Matrix::zeros(6, 4);
            for t in 1..=1500 {
                let g = w.sub(&target).scale(2.0);
                opt.update(&mut w, &g, t, 0.02);
                if let Some(s) = drain {
                    s.wait_idle();
                }
            }
            w
        };
        let w_inline = run(Soap::new(6, 4, h_base()), None);
        let mut async_opt = Soap::new(6, 4, h_base());
        assert!(async_opt.attach_async(&svc));
        let w_async = run(async_opt, Some(&*svc));

        // Both converge; the delayed basis costs at most a whisker.
        assert!(w_inline.max_abs_diff(&target) < 0.1);
        assert!(
            w_async.max_abs_diff(&target) < 0.12,
            "async SOAP failed to converge: {}",
            w_async.max_abs_diff(&target)
        );
    }

    #[test]
    fn attach_async_refuses_identity_only_layers() {
        let svc = Arc::new(RefreshService::new(1));
        let h = Hyper { max_precond_dim: 0, ..Hyper::default() };
        let mut opt = Soap::new(5, 7, h);
        assert!(!opt.attach_async(&svc), "nothing to refresh ⇒ stay inline");
        assert_eq!(opt.basis_snapshot_step(), None);
    }

    #[test]
    fn inline_refresh_phase_staggers_the_schedule() {
        // φ = 2, f = 5 ⇒ refreshes at t = 2, 7, 12 … Verify via basis_step.
        let h = Hyper { refresh_phase: 2, ..h_base() };
        let mut opt = Soap::new(4, 4, h);
        let mut rng = Rng::new(50);
        let mut w = Matrix::zeros(4, 4);
        for t in 1..=8 {
            let g = Matrix::randn(&mut rng, 4, 4, 1.0);
            opt.update(&mut w, &g, t, 0.01);
            let expect = match t {
                1 => 1, // init
                2..=6 => 2,
                _ => 7,
            };
            assert_eq!(opt.basis_snapshot_step(), Some(expect), "at t={t}");
        }
    }

    #[test]
    fn v_adapts_between_refreshes_unlike_shampoo() {
        // The core SOAP property: second moment changes on every step even
        // with a huge preconditioning frequency.
        let h = Hyper { precond_freq: 1000, weight_decay: 0.0, ..Hyper::default() };
        let mut opt = Soap::new(4, 4, h);
        let mut rng = Rng::new(47);
        let mut w = Matrix::zeros(4, 4);
        let g = Matrix::randn(&mut rng, 4, 4, 1.0);
        opt.update(&mut w, &g, 1, 0.01);
        let v1 = opt.v.as_ref().unwrap().clone();
        let g = Matrix::randn(&mut rng, 4, 4, 1.0);
        opt.update(&mut w, &g, 2, 0.01);
        let v2 = opt.v.as_ref().unwrap().clone();
        assert!(v1.max_abs_diff(&v2) > 0.0);
    }
}
