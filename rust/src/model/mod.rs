//! Native model substrate: a hand-backpropped MLP language model used for
//! artifact-free optimizer testing and fast native benches. The paper-scale
//! transformer lives in `python/compile/model.py` and reaches Rust as HLO
//! artifacts (see [`crate::runtime`]).

pub mod nplm;

pub use nplm::{gelu, gelu_grad, init_params, loss_and_grads, NplmConfig};
