//! Native neural probabilistic language model (Bengio et al. 2003 style):
//! embedding → concat(k context tokens) → GeLU MLP → softmax over vocab,
//! with hand-written backprop.
//!
//! Purpose (DESIGN.md §3.3): an artifact-free language-modeling substrate so
//! optimizer behaviour (loss curves, frequency ablations, Claim 1 checks)
//! can be unit/property-tested and benchmarked in pure Rust. The paper-scale
//! experiments use the JAX transformer artifacts; integration tests tie the
//! two together.

use crate::data::Batch;
use crate::linalg::{Matrix, TensorShape};
use crate::util::rng::Rng;

/// GeLU (tanh approximation, as in the paper's models).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/π)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// d/dx GeLU (tanh approximation).
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.797_884_6;
    let u = C * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    let du = C * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

#[derive(Clone, Copy, Debug)]
pub struct NplmConfig {
    pub vocab: usize,
    /// Context length (tokens of history fed to the MLP).
    pub context: usize,
    /// Embedding dim.
    pub dim: usize,
    /// Hidden width.
    pub hidden: usize,
    /// Declare W1 as the rank-3 convolution kernel it actually is
    /// (`[context, dim, hidden]` — a width-`context` conv over the embedded
    /// history, carried as its `(context·dim) × hidden` GEMM fold). The
    /// forward/backward math is identical either way; the optimizer sees a
    /// genuine rank-3 parameter and preconditions it per mode (the
    /// `nplm-conv` model preset).
    pub conv: bool,
}

impl NplmConfig {
    pub fn tiny() -> Self {
        Self { vocab: 64, context: 4, dim: 16, hidden: 32, conv: false }
    }

    /// Parameter shapes in canonical order: [E, W1, W2] — always the 2-D
    /// carrier folds the forward/backward GEMMs use.
    pub fn shapes(&self) -> Vec<(usize, usize)> {
        self.tensor_shapes().iter().map(|s| s.carrier()).collect()
    }

    /// True tensor shapes of the parameters: with `conv`, W1 is the rank-3
    /// `[context, dim, hidden]` kernel; otherwise its 2-D fold. Each
    /// shape's [`TensorShape::carrier`] equals the matching [`Self::shapes`]
    /// entry, so gradients and checkpoints are unchanged.
    pub fn tensor_shapes(&self) -> Vec<TensorShape> {
        vec![
            TensorShape::matrix(self.vocab, self.dim),
            if self.conv {
                TensorShape::new(vec![self.context, self.dim, self.hidden])
            } else {
                TensorShape::matrix(self.context * self.dim, self.hidden)
            },
            TensorShape::matrix(self.hidden, self.vocab),
        ]
    }

    pub fn num_params(&self) -> usize {
        self.shapes().iter().map(|&(m, n)| m * n).sum()
    }
}

/// Initialize parameters (truncated-normal-ish: plain normal with the usual
/// 1/√fan_in scaling).
pub fn init_params(cfg: &NplmConfig, rng: &mut Rng) -> Vec<Matrix> {
    cfg.shapes()
        .iter()
        .map(|&(m, n)| Matrix::randn(rng, m, n, 1.0 / (m as f32).sqrt()))
        .collect()
}

/// Forward + backward over a [`Batch`]: treats every position with at least
/// `context` predecessors in its row as one example. Returns
/// `(mean loss in nats, grads aligned with params)`.
pub fn loss_and_grads(cfg: &NplmConfig, params: &[Matrix], batch: &Batch) -> (f32, Vec<Matrix>) {
    let [e, w1, w2] = params else { panic!("expected 3 params") };
    assert_eq!(e.rows, cfg.vocab);
    let k = cfg.context;
    let d = cfg.dim;

    // Gather examples: context windows within each row.
    let mut ctxs: Vec<&[u32]> = Vec::new();
    let mut tgts: Vec<u32> = Vec::new();
    for b in 0..batch.batch {
        let row = &batch.tokens[b * batch.seq..(b + 1) * batch.seq];
        let trow = &batch.targets[b * batch.seq..(b + 1) * batch.seq];
        for s in (k - 1)..batch.seq {
            ctxs.push(&row[s + 1 - k..=s]);
            tgts.push(trow[s]);
        }
    }
    let n = ctxs.len();
    assert!(n > 0, "sequence shorter than context");

    // x: n × (k·d) concatenated embeddings.
    let mut x = Matrix::zeros(n, k * d);
    for (i, ctx) in ctxs.iter().enumerate() {
        for (j, &tok) in ctx.iter().enumerate() {
            let erow = e.row(tok as usize);
            x.row_mut(i)[j * d..(j + 1) * d].copy_from_slice(erow);
        }
    }

    // Hidden pre-activation and activation.
    let pre = x.matmul(w1); // n × h
    let h = pre.map(gelu);
    let logits = h.matmul(w2); // n × vocab

    // Softmax cross-entropy, numerically stable; dlogits = (p − onehot)/n.
    let mut loss = 0.0f64;
    let mut dlogits = Matrix::zeros(n, cfg.vocab);
    for i in 0..n {
        let row = logits.row(i);
        let maxv = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut z = 0.0f64;
        for &v in row {
            z += ((v - maxv) as f64).exp();
        }
        let lse = maxv as f64 + z.ln();
        let t = tgts[i] as usize;
        loss += lse - logits.at(i, t) as f64;
        let drow = dlogits.row_mut(i);
        for (j, &v) in row.iter().enumerate() {
            let p = ((v as f64 - lse).exp()) as f32;
            drow[j] = p / n as f32;
        }
        drow[t] -= 1.0 / n as f32;
    }
    let loss = (loss / n as f64) as f32;

    // Backprop.
    let dw2 = h.matmul_tn(&dlogits);
    let dh = dlogits.matmul_nt(w2);
    let dpre = dh.zip(&pre, |g, x| g * gelu_grad(x));
    let dw1 = x.matmul_tn(&dpre);
    let dx = dpre.matmul_nt(w1);

    // Embedding gradient: scatter-add context slices.
    let mut de = Matrix::zeros(cfg.vocab, d);
    for (i, ctx) in ctxs.iter().enumerate() {
        for (j, &tok) in ctx.iter().enumerate() {
            let src = &dx.row(i)[j * d..(j + 1) * d];
            let dst = de.row_mut(tok as usize);
            for (a, &b) in dst.iter_mut().zip(src) {
                *a += b;
            }
        }
    }

    (loss, vec![de, dw1, dw2])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{BatchStream, CorpusSpec};

    fn toy_batch(cfg: &NplmConfig, seed: u64) -> Batch {
        let spec = CorpusSpec { vocab_size: cfg.vocab, zipf_alpha: 1.2, seed, stream: 0 };
        BatchStream::new(spec, 2, 12, 0, 1).next_batch()
    }

    #[test]
    fn initial_loss_near_log_vocab() {
        let cfg = NplmConfig::tiny();
        let mut rng = Rng::new(70);
        let params = init_params(&cfg, &mut rng);
        let batch = toy_batch(&cfg, 1);
        let (loss, _) = loss_and_grads(&cfg, &params, &batch);
        let expect = (cfg.vocab as f32).ln();
        assert!((loss - expect).abs() < 0.5, "loss {loss} vs ln V {expect}");
    }

    #[test]
    fn gradients_match_finite_differences() {
        let cfg = NplmConfig { vocab: 12, context: 2, dim: 4, hidden: 6, conv: false };
        let mut rng = Rng::new(71);
        let mut params = init_params(&cfg, &mut rng);
        let batch = toy_batch(&cfg, 2);
        let (_, grads) = loss_and_grads(&cfg, &params, &batch);

        let eps = 1e-2f32;
        let mut checked = 0;
        for pi in 0..params.len() {
            // Probe a few entries per tensor.
            let probes = [(0usize, 0usize), (params[pi].rows - 1, params[pi].cols - 1)];
            for &(i, j) in &probes {
                let orig = params[pi].at(i, j);
                params[pi].set(i, j, orig + eps);
                let (lp, _) = loss_and_grads(&cfg, &params, &batch);
                params[pi].set(i, j, orig - eps);
                let (lm, _) = loss_and_grads(&cfg, &params, &batch);
                params[pi].set(i, j, orig);
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads[pi].at(i, j);
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
                    "param {pi} ({i},{j}): fd {fd} vs analytic {an}"
                );
                checked += 1;
            }
        }
        assert_eq!(checked, 6);
    }

    #[test]
    fn gelu_grad_matches_fd() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let eps = 1e-3;
            let fd = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!((fd - gelu_grad(x)).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn sgd_on_grads_reduces_loss() {
        let cfg = NplmConfig::tiny();
        let mut rng = Rng::new(72);
        let mut params = init_params(&cfg, &mut rng);
        let batch = toy_batch(&cfg, 3);
        let (l0, _) = loss_and_grads(&cfg, &params, &batch);
        for _ in 0..60 {
            let (_, grads) = loss_and_grads(&cfg, &params, &batch);
            for (p, g) in params.iter_mut().zip(&grads) {
                p.axpy_inplace(-0.5, g);
            }
        }
        let (l1, _) = loss_and_grads(&cfg, &params, &batch);
        assert!(l1 < l0 - 0.5, "loss {l0} → {l1}");
    }

    #[test]
    fn shapes_roundtrip() {
        let cfg = NplmConfig::tiny();
        let mut rng = Rng::new(73);
        let params = init_params(&cfg, &mut rng);
        for (p, &(m, n)) in params.iter().zip(&cfg.shapes()) {
            assert_eq!((p.rows, p.cols), (m, n));
        }
        assert_eq!(cfg.num_params(), 64 * 16 + 64 * 32 + 32 * 64);
    }

    #[test]
    fn conv_variant_declares_rank3_w1_with_same_carrier() {
        let cfg = NplmConfig { conv: true, ..NplmConfig::tiny() };
        let ts = cfg.tensor_shapes();
        assert_eq!(ts[1].dims(), &[cfg.context, cfg.dim, cfg.hidden]);
        // Carriers (and therefore gradients, params, checkpoints) are the
        // SAME matrices as the non-conv model — only the optimizer's view
        // of W1 changes.
        let plain = NplmConfig { conv: false, ..cfg };
        assert_eq!(cfg.shapes(), plain.shapes());
        let mut rng = Rng::new(74);
        let params = init_params(&cfg, &mut rng);
        for (p, s) in params.iter().zip(&ts) {
            assert_eq!((p.rows, p.cols), s.carrier());
        }
    }
}
