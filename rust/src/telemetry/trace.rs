//! Low-overhead span tracing with a Chrome trace-event JSON exporter.
//!
//! [`span`] / [`span_layer`] return a scoped guard; when telemetry is
//! enabled the guard's `Drop` records one [`SpanEvent`] into a per-thread
//! ring buffer. When disabled the guard is inert: no clock read, no
//! thread-local access, no allocation. Recording when enabled is also
//! allocation-free in steady state — each thread's ring is a fixed-capacity
//! buffer pre-filled at registration (the one-time registration allocation
//! lands during session warm-up), and span names are `&'static str`.
//!
//! [`write_chrome_trace`] drains every ring into a Chrome trace-event JSON
//! array of matched `"B"`/`"E"` duration events, ready for
//! `chrome://tracing` or <https://ui.perfetto.dev>.

use std::cell::RefCell;
use std::path::Path;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Per-thread ring capacity in events. At ~48 bytes/event this is ~0.8 MB
/// per recording thread; a long run keeps the most recent window, which is
/// the part worth looking at in a trace viewer anyway.
const RING_CAP: usize = 16_384;

/// Sentinel for spans not attached to a particular layer/basis.
pub const NO_LAYER: u64 = u64::MAX;

/// One completed span, timestamped in microseconds since the process trace
/// epoch (first span or drain after program start).
#[derive(Clone, Copy, Debug)]
pub struct SpanEvent {
    pub name: &'static str,
    pub cat: &'static str,
    /// Layer/basis id for per-layer spans, [`NO_LAYER`] otherwise.
    pub layer: u64,
    pub start_us: u64,
    pub dur_us: u64,
    pub tid: u32,
}

const EMPTY: SpanEvent =
    SpanEvent { name: "", cat: "", layer: NO_LAYER, start_us: 0, dur_us: 0, tid: 0 };

/// Monotonic epoch all span timestamps are relative to.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

struct Ring {
    buf: Vec<SpanEvent>,
    /// Next write index.
    head: usize,
    /// Number of valid events (saturates at capacity; oldest overwritten).
    len: usize,
}

impl Ring {
    fn new() -> Self {
        Ring { buf: vec![EMPTY; RING_CAP], head: 0, len: 0 }
    }

    fn push(&mut self, ev: SpanEvent) {
        self.buf[self.head] = ev;
        self.head = (self.head + 1) % self.buf.len();
        self.len = (self.len + 1).min(self.buf.len());
    }

    fn drain_into(&mut self, out: &mut Vec<SpanEvent>) {
        let cap = self.buf.len();
        let start = (self.head + cap - self.len) % cap;
        for i in 0..self.len {
            out.push(self.buf[(start + i) % cap]);
        }
        self.head = 0;
        self.len = 0;
    }
}

/// All registered per-thread rings (rings outlive their threads so a drain
/// after a worker pool shuts down still sees its spans).
fn registry() -> &'static Mutex<Vec<Arc<Mutex<Ring>>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<Mutex<Ring>>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

static NEXT_TID: AtomicU32 = AtomicU32::new(1);

struct LocalRing {
    ring: Arc<Mutex<Ring>>,
    tid: u32,
}

thread_local! {
    static LOCAL: RefCell<Option<LocalRing>> = const { RefCell::new(None) };
}

fn record(name: &'static str, cat: &'static str, layer: u64, start: Instant) {
    let now = Instant::now();
    let start_us = start.saturating_duration_since(epoch()).as_micros() as u64;
    let dur_us = now.saturating_duration_since(start).as_micros() as u64;
    LOCAL.with(|slot| {
        let mut slot = slot.borrow_mut();
        let local = slot.get_or_insert_with(|| {
            let ring = Arc::new(Mutex::new(Ring::new()));
            lock(registry()).push(Arc::clone(&ring));
            LocalRing { ring, tid: NEXT_TID.fetch_add(1, Ordering::Relaxed) }
        });
        let tid = local.tid;
        lock(&local.ring).push(SpanEvent { name, cat, layer, start_us, dur_us, tid });
    });
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Scoped span: records on drop when telemetry was enabled at creation.
#[must_use = "a span measures the scope it is bound to; bind it to a variable"]
pub struct SpanGuard {
    /// `None` when telemetry was disabled at creation — fully inert.
    start: Option<Instant>,
    name: &'static str,
    cat: &'static str,
    layer: u64,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            record(self.name, self.cat, self.layer, start);
        }
    }
}

/// Open a span covering the enclosing scope. Free when telemetry is off.
#[inline]
pub fn span(name: &'static str, cat: &'static str) -> SpanGuard {
    span_layer(name, cat, NO_LAYER)
}

/// Open a span tagged with a layer/basis id (shows as `args.layer` in the
/// exported trace).
#[inline]
pub fn span_layer(name: &'static str, cat: &'static str, layer: u64) -> SpanGuard {
    let start = if super::enabled() { Some(Instant::now()) } else { None };
    SpanGuard { start, name, cat, layer }
}

/// Drain every thread's ring into one chronologically-ordered list. Clears
/// the rings; intended for end-of-run export and tests.
pub fn drain() -> Vec<SpanEvent> {
    let rings = lock(registry());
    let mut out = Vec::new();
    for ring in rings.iter() {
        lock(ring).drain_into(&mut out);
    }
    out.sort_by_key(|e| e.start_us);
    out
}

/// Render spans as a Chrome trace-event JSON document: an object with a
/// `traceEvents` array of matched `"B"`/`"E"` pairs, one pair per span,
/// ordered so that within each thread the begin/end events nest properly.
pub fn chrome_trace_json(events: &[SpanEvent]) -> Json {
    // (ts, kind, span index); kind 0 = end, 1 = begin so an end at t sorts
    // before a begin at t (back-to-back siblings stay disjoint).
    let mut marks: Vec<(u64, u8, usize)> = Vec::with_capacity(events.len() * 2);
    for (i, e) in events.iter().enumerate() {
        // A span shorter than the 1 µs clock tick still needs end > begin
        // for the B/E stream to nest; clamp its duration up to one tick.
        let dur = e.dur_us.max(1);
        marks.push((e.start_us, 1, i));
        marks.push((e.start_us + dur, 0, i));
    }
    marks.sort_by(|a, b| {
        let ea = &events[a.2];
        let eb = &events[b.2];
        a.0.cmp(&b.0)
            .then(a.1.cmp(&b.1))
            // Tied ends: the later-started (inner) span closes first.
            .then(if a.1 == 0 { eb.start_us.cmp(&ea.start_us) } else { std::cmp::Ordering::Equal })
            // Tied begins: the longer (outer) span opens first.
            .then(eb.dur_us.cmp(&ea.dur_us))
    });
    let mut out = Vec::with_capacity(marks.len());
    for (ts, kind, i) in marks {
        let e = &events[i];
        let mut fields = vec![
            ("name", Json::str(e.name)),
            ("cat", Json::str(e.cat)),
            ("ph", Json::str(if kind == 1 { "B" } else { "E" })),
            ("ts", Json::num(ts as f64)),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(e.tid as f64)),
        ];
        if kind == 1 && e.layer != NO_LAYER {
            fields.push(("args", Json::obj(vec![("layer", Json::num(e.layer as f64))])));
        }
        out.push(Json::obj(fields));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Drain all recorded spans and write them to `path` as Chrome trace-event
/// JSON. Returns the number of spans exported.
pub fn write_chrome_trace(path: &Path) -> std::io::Result<usize> {
    let events = drain();
    let doc = chrome_trace_json(&events);
    std::fs::write(path, doc.dump())?;
    Ok(events.len())
}

/// Serializes tests that toggle the process-wide telemetry flag or inspect
/// the global span rings. Public so integration tests can share it.
#[doc(hidden)]
pub fn test_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    lock(&LOCK)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = test_lock();
        super::super::set_enabled(false);
        drain();
        {
            let _s = span("test.noop", "test");
        }
        assert!(drain().is_empty());
    }

    #[test]
    fn enabled_spans_round_trip_through_chrome_export() {
        let _g = test_lock();
        super::super::set_enabled(true);
        drain();
        {
            let _outer = span("test.outer", "test");
            let _inner = span_layer("test.inner", "test", 3);
        }
        super::super::set_enabled(false);
        let events = drain();
        assert_eq!(events.len(), 2);
        // Inner drops first, so it is recorded first but starts no earlier.
        assert!(events.iter().any(|e| e.name == "test.outer" && e.layer == NO_LAYER));
        assert!(events.iter().any(|e| e.name == "test.inner" && e.layer == 3));
        let doc = chrome_trace_json(&events);
        let parsed = Json::parse(&doc.dump()).unwrap();
        let evs = parsed.get("traceEvents").as_arr().unwrap();
        assert_eq!(evs.len(), 4);
        let b = evs.iter().filter(|e| e.get("ph").as_str() == Some("B")).count();
        let e = evs.iter().filter(|e| e.get("ph").as_str() == Some("E")).count();
        assert_eq!(b, 2);
        assert_eq!(e, 2);
        // The layer tag rides on the begin event.
        assert!(evs.iter().any(|ev| {
            ev.get("ph").as_str() == Some("B")
                && ev.get("name").as_str() == Some("test.inner")
                && ev.get("args").get("layer").as_f64() == Some(3.0)
        }));
    }

    #[test]
    fn ring_overwrites_oldest_when_full() {
        let mut ring = Ring::new();
        for i in 0..(RING_CAP + 10) {
            ring.push(SpanEvent { start_us: i as u64, ..EMPTY });
        }
        let mut out = Vec::new();
        ring.drain_into(&mut out);
        assert_eq!(out.len(), RING_CAP);
        assert_eq!(out.first().unwrap().start_us, 10);
        assert_eq!(out.last().unwrap().start_us, (RING_CAP + 9) as u64);
    }
}
