//! Telemetry: span tracing, metrics, and optimizer health introspection.
//!
//! The paper's central empirical claims are about *where time goes* (Fig. 7
//! overhead accounting) and *how stale bases degrade loss* (Fig. 1 frequency
//! sweep). This module makes both observable without perturbing the math:
//!
//! - [`trace`] — low-overhead span tracing. [`span`]/[`span_layer`] scoped
//!   timers record into per-thread ring buffers;
//!   [`trace::write_chrome_trace`] exports Chrome trace-event JSON
//!   (openable in `chrome://tracing` or <https://ui.perfetto.dev>). Spans
//!   cover the step phases (`step.data` / `step.grad` / `step.update` /
//!   `step.refresh`), the engine hot path inside `Composed::update`
//!   (`engine.project` / `engine.moment` / `engine.project_back`), and
//!   every eigenbasis refresh (`refresh.init` / `refresh.inline` /
//!   `refresh.bg`, tagged with the per-layer basis id).
//! - [`metrics`] — a counters/gauges/histograms [`metrics::Registry`] with
//!   Prometheus text exposition ([`metrics::Registry::prometheus`]).
//!   Well-known series: `soap_refresh_shed_total` (snapshots skipped while
//!   a previous refresh was in flight), `soap_refresh_latency_seconds`
//!   (background refresh task latency histogram),
//!   `soap_refresh_queue_depth` (pending background refreshes).
//! - Per-layer optimizer health flows through the
//!   [`crate::session::MetricsSink`] seam as
//!   [`crate::session::HealthSnapshot`] records: gradient/update norms,
//!   per-layer basis staleness, refresh-service queue depth + shed count +
//!   latency quantiles, refresh `ThreadPool` utilization, and the
//!   whitening-quality metric (off-diagonal mass of the rotated second
//!   moment, sampled every k-th refresh).
//!
//! ## Provably free when disabled
//!
//! Everything is gated on one relaxed [`AtomicBool`]. With telemetry off
//! (the default) [`span`] returns an inert guard — no clock read, no
//! thread-local access, no allocation — and every metrics call site skips
//! its recording. The steady-state optimizer step stays zero-alloc
//! (`rust/tests/alloc_step.rs` asserts this with telemetry off AND on:
//! enabled-mode recording writes into preallocated rings), and telemetry
//! never reads or writes any f32 the update path consumes, so trajectories
//! are bitwise identical either way (`rust/tests/telemetry.rs`).

pub mod metrics;
pub mod trace;

use std::sync::atomic::{AtomicBool, Ordering};

pub use trace::{span, span_layer, SpanGuard};

/// Global enable flag. Relaxed loads: the gate is advisory (a span that
/// races an enable/disable edge is merely recorded or skipped — there is no
/// ordering dependency on other memory).
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is telemetry recording enabled?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn telemetry recording on or off (process-wide). Sessions built with
/// `SessionBuilder::telemetry(true)` call this; tests toggle it directly.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_by_default_and_toggleable() {
        // Serialize against sibling tests that flip the global flag.
        let _lock = trace::test_lock();
        assert!(!enabled());
        set_enabled(true);
        assert!(enabled());
        set_enabled(false);
        assert!(!enabled());
    }
}
