//! Counters, gauges, and histograms with Prometheus text exposition.
//!
//! All instruments are lock-free atomics so hot paths (refresh workers, the
//! GEMM pool) can record without contention or allocation; the registry's
//! `Mutex` is touched only at get-or-create and snapshot time. Instruments
//! are leaked (`Box::leak`) so call sites hold `&'static` references and a
//! lookup is paid once, not per event.
//!
//! Histograms use power-of-two buckets over `[1 ns, ~1100 s)` — plenty of
//! resolution for latencies — plus exact `count`/`sum`/`min`/`max`, which
//! makes the common quantile edge cases exact: an empty histogram reports
//! `NaN`, and single-sample / all-equal histograms report the sample value
//! itself (no bucket interpolation error).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

use crate::util::json::Json;

/// Monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
    fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

/// Last-write-wins instantaneous value (stored as `f64` bits).
#[derive(Debug)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Default for Gauge {
    fn default() -> Self {
        Gauge { bits: AtomicU64::new(0f64.to_bits()) }
    }
}

impl Gauge {
    pub fn set(&self, x: f64) {
        self.bits.store(x.to_bits(), Ordering::Relaxed);
    }
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
    fn reset(&self) {
        self.set(0.0);
    }
}

/// Histogram bucket count: powers of two from `BUCKET_BASE` up. Bucket 0
/// holds everything below `BUCKET_BASE` (including non-positive values);
/// bucket `i ≥ 1` covers `[BUCKET_BASE·2^(i-1), BUCKET_BASE·2^i)`; the last
/// bucket also absorbs overflow.
const N_BUCKETS: usize = 44;
const BUCKET_BASE: f64 = 1e-9;

fn bucket_index(x: f64) -> usize {
    if x.is_nan() || x <= BUCKET_BASE {
        return 0;
    }
    let i = (x / BUCKET_BASE).log2().floor() as usize + 1;
    i.min(N_BUCKETS - 1)
}

fn bucket_bounds(i: usize) -> (f64, f64) {
    if i == 0 {
        (0.0, BUCKET_BASE)
    } else {
        (BUCKET_BASE * (1u64 << (i - 1)) as f64, BUCKET_BASE * (1u64 << i) as f64)
    }
}

/// Lock-free latency/size histogram with exact count/sum/min/max.
#[derive(Debug)]
pub struct Histogram {
    count: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
    buckets: [AtomicU64; N_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl Histogram {
    pub fn observe(&self, x: f64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        let _ = self.sum_bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
            Some((f64::from_bits(b) + x).to_bits())
        });
        let _ = self.min_bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
            if x < f64::from_bits(b) { Some(x.to_bits()) } else { None }
        });
        let _ = self.max_bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
            if x > f64::from_bits(b) { Some(x.to_bits()) } else { None }
        });
        self.buckets[bucket_index(x)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }
    pub fn min(&self) -> f64 {
        if self.count() == 0 {
            f64::NAN
        } else {
            f64::from_bits(self.min_bits.load(Ordering::Relaxed))
        }
    }
    pub fn max(&self) -> f64 {
        if self.count() == 0 {
            f64::NAN
        } else {
            f64::from_bits(self.max_bits.load(Ordering::Relaxed))
        }
    }
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 { f64::NAN } else { self.sum() / n as f64 }
    }

    /// Approximate quantile, `q ∈ [0, 1]`. Exact for the edge cases: `NaN`
    /// when empty; the sample value when all samples are equal (covers the
    /// single-sample case). Otherwise linear interpolation inside the
    /// matching bucket, clamped to the observed `[min, max]`.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        let (min, max) = (self.min(), self.max());
        if min == max {
            return min;
        }
        let rank = (q * n as f64).ceil().max(1.0);
        let mut cum = 0u64;
        for i in 0..N_BUCKETS {
            let c = self.buckets[i].load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if (cum + c) as f64 >= rank {
                let (lo, hi) = bucket_bounds(i);
                let frac = (rank - cum as f64) / c as f64;
                return (lo + frac * (hi - lo)).clamp(min, max);
            }
            cum += c;
        }
        max
    }

    fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
        self.sum_bits.store(0f64.to_bits(), Ordering::Relaxed);
        self.min_bits.store(f64::INFINITY.to_bits(), Ordering::Relaxed);
        self.max_bits.store(f64::NEG_INFINITY.to_bits(), Ordering::Relaxed);
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
    }
}

/// Named instrument registry. Get-or-create hands out `&'static` references
/// (instruments are leaked — they live for the process, like the series
/// they describe).
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, &'static Counter>>,
    gauges: Mutex<BTreeMap<String, &'static Gauge>>,
    histograms: Mutex<BTreeMap<String, &'static Histogram>>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl Registry {
    pub fn counter(&self, name: &str) -> &'static Counter {
        let mut map = lock(&self.counters);
        if let Some(c) = map.get(name) {
            return c;
        }
        let c: &'static Counter = Box::leak(Box::default());
        map.insert(name.to_string(), c);
        c
    }

    pub fn gauge(&self, name: &str) -> &'static Gauge {
        let mut map = lock(&self.gauges);
        if let Some(g) = map.get(name) {
            return g;
        }
        let g: &'static Gauge = Box::leak(Box::default());
        map.insert(name.to_string(), g);
        g
    }

    pub fn histogram(&self, name: &str) -> &'static Histogram {
        let mut map = lock(&self.histograms);
        if let Some(h) = map.get(name) {
            return h;
        }
        let h: &'static Histogram = Box::leak(Box::default());
        map.insert(name.to_string(), h);
        h
    }

    /// Zero every registered instrument (instruments stay registered).
    pub fn reset(&self) {
        for c in lock(&self.counters).values() {
            c.reset();
        }
        for g in lock(&self.gauges).values() {
            g.reset();
        }
        for h in lock(&self.histograms).values() {
            h.reset();
        }
    }

    /// Prometheus text exposition (format 0.0.4). Counters and gauges emit
    /// one sample each; histograms emit a summary (`quantile` labels plus
    /// `_sum`/`_count`).
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        for (name, c) in lock(&self.counters).iter() {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {}", c.get());
        }
        for (name, g) in lock(&self.gauges).iter() {
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {}", g.get());
        }
        for (name, h) in lock(&self.histograms).iter() {
            let _ = writeln!(out, "# TYPE {name} summary");
            for q in [0.5, 0.9, 0.99] {
                let v = h.quantile(q);
                let _ = writeln!(out, "{name}{{quantile=\"{q}\"}} {v}");
            }
            let _ = writeln!(out, "{name}_sum {}", h.sum());
            let _ = writeln!(out, "{name}_count {}", h.count());
        }
        out
    }

    /// JSON snapshot of every instrument (for JSONL metric streams).
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(String, Json)> = Vec::new();
        for (name, c) in lock(&self.counters).iter() {
            fields.push((name.clone(), Json::num(c.get() as f64)));
        }
        for (name, g) in lock(&self.gauges).iter() {
            fields.push((name.clone(), Json::num(g.get())));
        }
        for (name, h) in lock(&self.histograms).iter() {
            fields.push((
                name.clone(),
                Json::obj(vec![
                    ("count", Json::num(h.count() as f64)),
                    ("sum", Json::num(h.sum())),
                    ("p50", Json::num(h.quantile(0.5))),
                    ("p99", Json::num(h.quantile(0.99))),
                ]),
            ));
        }
        Json::Obj(fields.into_iter().collect())
    }
}

/// Process-wide registry.
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::default)
}

// ---- well-known series ---------------------------------------------------
// Accessors cache the registry lookup so hot paths (refresh gating, pool
// workers) touch only the instrument's atomics.

/// Refresh snapshots skipped because the previous refresh of the same basis
/// was still in flight (`BasisHandle::try_begin_refresh` said no).
pub fn refresh_shed_total() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    C.get_or_init(|| registry().counter("soap_refresh_shed_total"))
}

/// Background refresh tasks enqueued to the refresh service.
pub fn refresh_enqueued_total() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    C.get_or_init(|| registry().counter("soap_refresh_enqueued_total"))
}

/// Wall-clock latency of one background refresh task, seconds.
pub fn refresh_latency_seconds() -> &'static Histogram {
    static H: OnceLock<&'static Histogram> = OnceLock::new();
    H.get_or_init(|| registry().histogram("soap_refresh_latency_seconds"))
}

/// Pending background refreshes at the last health sample.
pub fn refresh_queue_depth() -> &'static Gauge {
    static G: OnceLock<&'static Gauge> = OnceLock::new();
    G.get_or_init(|| registry().gauge("soap_refresh_queue_depth"))
}

/// Distributed-protocol frames sent by this process (all ranks share the
/// registry under the mem transport; per-rank attribution lives in the
/// communicator's own counters → `HealthSnapshot::ranks`).
pub fn dist_frames_sent_total() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    C.get_or_init(|| registry().counter("soap_dist_frames_sent_total"))
}

/// Distributed-protocol frames received by this process.
pub fn dist_frames_recv_total() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    C.get_or_init(|| registry().counter("soap_dist_frames_recv_total"))
}

/// Distributed-protocol payload bytes sent by this process.
pub fn dist_bytes_sent_total() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    C.get_or_init(|| registry().counter("soap_dist_bytes_sent_total"))
}

/// Distributed-protocol payload bytes received by this process.
pub fn dist_bytes_recv_total() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    C.get_or_init(|| registry().counter("soap_dist_bytes_recv_total"))
}

/// Wall-clock seconds one rank spent inside the gradient fold-reduce
/// (send + receive + add, per step).
pub fn dist_allreduce_seconds() -> &'static Histogram {
    static H: OnceLock<&'static Histogram> = OnceLock::new();
    H.get_or_init(|| registry().histogram("soap_dist_allreduce_seconds"))
}

// Fault-tolerance counters below increment unconditionally (not gated on
// `telemetry::enabled()`): faults and guard trips are rare, and their counts
// must survive into health snapshots even on minimal-telemetry runs.

/// Faults fired by the seeded injection plan (`--fault-plan`): dropped /
/// duplicated / delayed frames, poisoned gradients and decompositions.
pub fn fault_injected_total() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    C.get_or_init(|| registry().counter("soap_fault_injected_total"))
}

/// Optimizer updates skipped by the numerical-health guard (non-finite
/// gradient or update direction under `GuardPolicy::SkipStep`).
pub fn step_skipped_total() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    C.get_or_init(|| registry().counter("soap_step_skipped_total"))
}

/// Refreshed bases rejected for non-finite factors; consumers kept the
/// previous publication (stale-basis grace).
pub fn basis_rejected_total() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    C.get_or_init(|| registry().counter("soap_basis_rejected_total"))
}

/// Transport-level retries: re-sends of injected frame drops plus connect
/// backoff rounds during rendezvous.
pub fn transport_retries_total() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    C.get_or_init(|| registry().counter("soap_transport_retries_total"))
}

/// Heartbeat frames written by this process's heartbeat thread.
pub fn heartbeats_sent_total() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    C.get_or_init(|| registry().counter("soap_heartbeats_sent_total"))
}

/// Longest current silence across peers, seconds (updated per heartbeat
/// tick; crossing `--dist-timeout` means a peer is presumed dead).
pub fn heartbeat_silence_seconds() -> &'static Gauge {
    static G: OnceLock<&'static Gauge> = OnceLock::new();
    G.get_or_init(|| registry().gauge("soap_heartbeat_silence_seconds"))
}

// Sweep-orchestrator series (`soap sweep`). Like the fault counters these
// are written unconditionally — the orchestrator is its own entry point and
// its health must be observable even without `--telemetry`.

/// Training jobs currently admitted and running in the sweep scheduler.
pub fn sweep_jobs_running() -> &'static Gauge {
    static G: OnceLock<&'static Gauge> = OnceLock::new();
    G.get_or_init(|| registry().gauge("soap_sweep_jobs_running"))
}

/// Sweep jobs finished successfully (includes jobs skipped on resume
/// because a prior run already completed them).
pub fn sweep_jobs_done() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    C.get_or_init(|| registry().counter("soap_sweep_jobs_done"))
}

/// Sweep jobs that ended as failed rows (guard aborts, injected faults,
/// panics, or estimated footprint above the whole budget).
pub fn sweep_jobs_failed() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    C.get_or_init(|| registry().counter("soap_sweep_jobs_failed"))
}

/// Global memory budget the sweep admission controller enforces, bytes.
pub fn sweep_mem_budget_bytes() -> &'static Gauge {
    static G: OnceLock<&'static Gauge> = OnceLock::new();
    G.get_or_init(|| registry().gauge("soap_sweep_mem_budget_bytes"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantile_edge_cases() {
        let h = Histogram::default();
        assert!(h.quantile(0.5).is_nan(), "empty histogram must report NaN");
        h.observe(0.125);
        assert_eq!(h.quantile(0.0), 0.125, "single sample is exact");
        assert_eq!(h.quantile(0.5), 0.125);
        assert_eq!(h.quantile(1.0), 0.125);
        for _ in 0..100 {
            h.observe(0.125);
        }
        assert_eq!(h.quantile(0.99), 0.125, "all-equal samples are exact");
        h.observe(4.0);
        let p50 = h.quantile(0.5);
        assert!((0.0625..=0.25).contains(&p50), "p50 {p50} should sit near 0.125");
        assert_eq!(h.quantile(1.0), 4.0, "q=1 lands in the max bucket, clamped to max");
        assert_eq!(h.min(), 0.125);
        assert_eq!(h.max(), 4.0);
        assert_eq!(h.count(), 102);
    }

    #[test]
    fn histogram_bucket_bounds_cover_value() {
        for &x in &[1e-10, 5e-9, 1e-6, 3.7e-3, 0.5, 1.0, 900.0, 1e9] {
            let i = bucket_index(x);
            let (lo, hi) = bucket_bounds(i);
            if i == 0 {
                assert!(x < hi);
            } else if i < N_BUCKETS - 1 {
                assert!(x >= lo && x < hi, "{x} not in [{lo}, {hi})");
            } else {
                assert!(x >= lo, "{x} below overflow bucket lower bound {lo}");
            }
        }
    }

    #[test]
    fn registry_get_or_create_is_idempotent() {
        let r = Registry::default();
        let a = r.counter("x_total") as *const Counter;
        let b = r.counter("x_total") as *const Counter;
        assert_eq!(a, b);
        r.counter("x_total").add(3);
        assert_eq!(r.counter("x_total").get(), 3);
        r.reset();
        assert_eq!(r.counter("x_total").get(), 0);
    }

    #[test]
    fn prometheus_exposition_shape() {
        let r = Registry::default();
        r.counter("a_total").add(2);
        r.gauge("b_depth").set(1.5);
        r.histogram("c_seconds").observe(0.25);
        let text = r.prometheus();
        assert!(text.contains("# TYPE a_total counter"));
        assert!(text.contains("a_total 2"));
        assert!(text.contains("# TYPE b_depth gauge"));
        assert!(text.contains("b_depth 1.5"));
        assert!(text.contains("# TYPE c_seconds summary"));
        assert!(text.contains("c_seconds_count 1"));
        assert!(text.contains("c_seconds{quantile=\"0.5\"} 0.25"));
    }

    #[test]
    fn counters_are_safe_under_contention() {
        let r = Registry::default();
        let c = r.counter("contended_total");
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 80_000);
    }
}
