//! ASCII line plots for bench reports (loss curves, sweeps) so every figure
//! regenerator prints a visual directly in the terminal, alongside its CSV.

/// Render multiple named series into a `width`×`height` character canvas.
/// Each series gets its own glyph; a legend and axis ranges are appended.
pub fn ascii_plot(
    series: &[(String, Vec<(f64, f64)>)],
    xlabel: &str,
    ylabel: &str,
    width: usize,
    height: usize,
) -> String {
    const GLYPHS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&', '~', '^'];
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|(_, p)| p.iter().copied())
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if pts.is_empty() {
        return "(no data)".to_string();
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-300 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-300 {
        ymax = ymin + 1.0;
    }

    let mut canvas = vec![vec![' '; width]; height];
    for (si, (_, p)) in series.iter().enumerate() {
        let g = GLYPHS[si % GLYPHS.len()];
        for &(x, y) in p {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let cx = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let cy = ((y - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            canvas[row][cx.min(width - 1)] = g;
        }
    }

    let mut out = String::new();
    out.push_str(&format!("  {ylabel}: [{ymin:.4} .. {ymax:.4}]\n"));
    for row in &canvas {
        out.push_str("  |");
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str("  +");
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("   {xlabel}: [{xmin:.4} .. {xmax:.4}]\n"));
    out.push_str("   legend: ");
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("{}={}  ", GLYPHS[si % GLYPHS.len()], name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plots_all_series_glyphs() {
        let s = vec![
            ("a".to_string(), vec![(0.0, 0.0), (1.0, 1.0)]),
            ("b".to_string(), vec![(0.0, 1.0), (1.0, 0.0)]),
        ];
        let p = ascii_plot(&s, "x", "y", 40, 10);
        assert!(p.contains('*'));
        assert!(p.contains('o'));
        assert!(p.contains("legend"));
    }

    #[test]
    fn empty_is_graceful() {
        assert_eq!(ascii_plot(&[], "x", "y", 10, 5), "(no data)");
    }

    #[test]
    fn constant_series_no_panic() {
        let s = vec![("c".to_string(), vec![(0.0, 2.0), (1.0, 2.0)])];
        let p = ascii_plot(&s, "x", "y", 20, 5);
        assert!(p.contains('*'));
    }

    #[test]
    fn nan_points_skipped() {
        let s = vec![("n".to_string(), vec![(0.0, f64::NAN), (1.0, 1.0)])];
        let p = ascii_plot(&s, "x", "y", 20, 5);
        assert!(p.contains('*'));
    }
}
