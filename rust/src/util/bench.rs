//! Benchmark harness (criterion stand-in).
//!
//! Each paper figure gets a `[[bench]] harness = false` binary that uses this
//! module: `Bencher` measures closures with warmup + repeated timed runs and
//! prints a fixed-width table (median / p10 / p90 / mean); `Report` collects
//! named series (e.g. loss curves per optimizer) and renders them as aligned
//! tables and ASCII plots, plus CSV files under `bench_results/`.

use std::time::Instant;

use super::plot;
use super::stats::Samples;

/// Measure a closure: `warmup` untimed runs then `iters` timed runs.
pub struct Bencher {
    pub warmup: usize,
    pub iters: usize,
}

#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub median_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
    pub mean_s: f64,
    pub iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup: 2, iters: 10 }
    }
}

impl Bencher {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Self { warmup, iters }
    }

    pub fn measure<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Samples::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        Measurement {
            name: name.to_string(),
            median_s: samples.median(),
            p10_s: samples.quantile(0.10),
            p90_s: samples.quantile(0.90),
            mean_s: samples.mean(),
            iters: self.iters,
        }
    }
}

pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Print a table of measurements with a relative column vs the first row.
pub fn print_table(title: &str, rows: &[Measurement]) {
    println!("\n== {title} ==");
    println!(
        "{:<40} {:>10} {:>10} {:>10} {:>8}",
        "case", "median", "p10", "p90", "rel"
    );
    let base = rows.first().map(|r| r.median_s).unwrap_or(1.0);
    for r in rows {
        println!(
            "{:<40} {:>10} {:>10} {:>10} {:>7.2}x",
            r.name,
            fmt_duration(r.median_s),
            fmt_duration(r.p10_s),
            fmt_duration(r.p90_s),
            r.median_s / base
        );
    }
}

/// Collected results for a figure: named (x, y) series, rendered as an ASCII
/// plot + aligned table + CSV dump.
#[derive(Default)]
pub struct Report {
    pub title: String,
    pub xlabel: String,
    pub ylabel: String,
    pub series: Vec<(String, Vec<(f64, f64)>)>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(title: &str, xlabel: &str, ylabel: &str) -> Self {
        Self {
            title: title.to_string(),
            xlabel: xlabel.to_string(),
            ylabel: ylabel.to_string(),
            ..Default::default()
        }
    }

    pub fn add_series(&mut self, name: &str, points: Vec<(f64, f64)>) {
        self.series.push((name.to_string(), points));
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Render plot + table to stdout and write `bench_results/<slug>.csv`.
    pub fn render_and_save(&self) {
        println!("\n==== {} ====", self.title);
        println!("{}", plot::ascii_plot(&self.series, &self.xlabel, &self.ylabel, 72, 20));
        // Summary table: final point of every series.
        println!("{:<34} {:>14} {:>14}", "series", "last x", "last y");
        for (name, pts) in &self.series {
            if let Some((x, y)) = pts.last() {
                println!("{name:<34} {x:>14.4} {y:>14.4}");
            }
        }
        for n in &self.notes {
            println!("note: {n}");
        }
        if let Err(e) = self.save_csv() {
            println!("warn: csv save failed: {e}");
        }
    }

    pub fn slug(&self) -> String {
        self.title
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect()
    }

    pub fn save_csv(&self) -> std::io::Result<()> {
        std::fs::create_dir_all("bench_results")?;
        let path = format!("bench_results/{}.csv", self.slug());
        let mut out = String::from("series,x,y\n");
        for (name, pts) in &self.series {
            for (x, y) in pts {
                out.push_str(&format!("{name},{x},{y}\n"));
            }
        }
        std::fs::write(path, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_reports_sane_times() {
        let b = Bencher::new(1, 5);
        let m = b.measure("sleep", || {
            std::thread::sleep(std::time::Duration::from_millis(2))
        });
        assert!(m.median_s >= 0.002);
        assert!(m.median_s < 0.2);
        assert!(m.p10_s <= m.p90_s);
    }

    #[test]
    fn fmt_duration_scales() {
        assert!(fmt_duration(2.5e-9).ends_with("ns"));
        assert!(fmt_duration(2.5e-5).ends_with("µs"));
        assert!(fmt_duration(2.5e-2).ends_with("ms"));
        assert!(fmt_duration(2.5).ends_with('s'));
    }

    #[test]
    fn report_slug_and_csv() {
        let mut r = Report::new("Fig 1: Loss / Curves", "step", "loss");
        r.add_series("soap", vec![(0.0, 5.0), (1.0, 4.0)]);
        assert_eq!(r.slug(), "fig_1__loss___curves");
        // CSV write into a temp cwd-relative dir; just exercise the path.
        r.save_csv().unwrap();
        let body = std::fs::read_to_string("bench_results/fig_1__loss___curves.csv").unwrap();
        assert!(body.contains("soap,0,5"));
    }
}
