//! Hand-rolled substrate modules (DESIGN.md §2).
//!
//! The build environment is offline; its cargo registry cache holds only the
//! `xla` crate's dependency closure, so the roles normally filled by `rand`,
//! `serde_json`, `clap`, `tokio`, `criterion`, and `proptest` are covered by
//! these small, tested modules.

pub mod bench;
pub mod cli;
pub mod json;
pub mod plot;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
