//! Deterministic pseudo-random number generation.
//!
//! The offline registry has no `rand` crate, so this module provides the
//! generators the rest of the workspace needs: a SplitMix64 seeder, a
//! xoshiro256++ core generator, and the samplers used by the synthetic data
//! pipeline (uniform, normal, Zipf, categorical) and by weight init.
//!
//! Everything here is deterministic given a seed; the coordinator's
//! reproducibility guarantees (same seed → same batch stream → same loss
//! curve) rest on that.

/// SplitMix64: used to expand a single `u64` seed into the xoshiro state.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller sample.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Construct from a seed; the state is expanded with SplitMix64 so that
    /// small consecutive seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, gauss_spare: None }
    }

    /// Derive an independent child stream (for per-worker / per-layer RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let a = self.next_u64();
        Rng::new(a ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)` (n > 0) without modulo bias (rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean / std as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with N(0, std²) samples — weight init helper.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf(α) sampler over `{0, …, n-1}` via precomputed CDF; the synthetic
/// corpus uses this for realistic (heavy-tailed) unigram statistics.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        // Binary search for the first cdf entry >= u.
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let z = Zipf::new(1000, 1.2);
        let mut r = Rng::new(5);
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            if z.sample(&mut r) < 10 {
                head += 1;
            }
        }
        // Top-10 of a Zipf(1.2) over 1000 symbols carries well over a third
        // of the mass.
        assert!(head as f64 > 0.35 * n as f64, "head={head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut root = Rng::new(1234);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(21);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }
}
