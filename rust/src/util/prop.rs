//! Mini property-based testing framework (proptest stand-in).
//!
//! A `Gen<T>` is a seeded generator; `check` runs a property over N generated
//! cases and, on failure, re-runs the case with a smaller "size" budget a few
//! times (shrinking-lite) before reporting the seed that reproduces it.
//!
//! Usage:
//! ```ignore
//! prop::check("qr orthogonal", 64, |rng| {
//!     let n = 1 + rng.below(16) as usize;
//!     let a = Matrix::randn(rng, n, n);
//!     let (q, _) = qr(&a);
//!     prop::assert_close(&(q.t().matmul(&q)), &Matrix::eye(n), 1e-4)
//! });
//! ```

use super::rng::Rng;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Assert two f32 slices are elementwise close; returns a CaseResult so
/// property closures can `?` it.
pub fn close_slices(a: &[f32], b: &[f32], tol: f32) -> CaseResult {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale || x.is_nan() != y.is_nan() {
            return Err(format!(
                "element {i}: {x} vs {y} (|Δ|={}, tol={tol})",
                (x - y).abs()
            ));
        }
    }
    Ok(())
}

/// Assert a scalar condition with a formatted message.
pub fn ensure(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `prop` over `cases` generated cases. Panics with the failing seed on
/// first failure. The environment variable `SOAP_PROP_SEED` pins the base
/// seed to reproduce failures.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> CaseResult,
{
    let base_seed = std::env::var("SOAP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0x50A9_0000_5eed_0001);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed={seed}): {msg}\n\
                 reproduce with SOAP_PROP_SEED={base_seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u64 parity roundtrip", 128, |rng| {
            let x = rng.next_u64();
            ensure(x.rotate_left(13).rotate_right(13) == x, "rotate roundtrip")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", 8, |_rng| Err("nope".to_string()));
    }

    #[test]
    fn close_slices_detects_mismatch() {
        assert!(close_slices(&[1.0, 2.0], &[1.0, 2.0], 1e-6).is_ok());
        assert!(close_slices(&[1.0, 2.0], &[1.0, 2.1], 1e-6).is_err());
        assert!(close_slices(&[1.0], &[1.0, 2.0], 1e-6).is_err());
    }

    #[test]
    fn close_slices_relative_tolerance() {
        // 1e6 vs 1e6+50 is within 1e-4 relative.
        assert!(close_slices(&[1.0e6], &[1.0e6 + 50.0], 1e-4).is_ok());
    }
}
