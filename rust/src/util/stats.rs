//! Small statistics helpers used by metrics, benches, and experiment fits:
//! streaming mean/variance (Welford), exact quantiles over stored samples,
//! and simple linear regression (used for sanity fits and throughput slopes).

/// Welford streaming mean/variance.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Sample store with exact quantiles — fine at bench scale (≤ millions).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }
    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Quantile by linear interpolation, q in [0,1].
    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let pos = q * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = pos - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }

    pub fn median(&mut self) -> f64 {
        self.quantile(0.5)
    }
    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.first().copied().unwrap_or(f64::NAN)
    }
    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.last().copied().unwrap_or(f64::NAN)
    }
}

/// Ordinary least squares fit y = a + b·x. Returns (a, b, r²).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Exponential moving average tracker (loss smoothing for plots).
#[derive(Clone, Debug)]
pub struct Ema {
    beta: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(beta: f64) -> Self {
        assert!((0.0..1.0).contains(&beta));
        Self { beta, value: None }
    }
    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.beta * v + (1.0 - self.beta) * x,
        };
        self.value = Some(v);
        v
    }
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / 5.0;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / 4.0;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let mut s = Samples::new();
        for i in 0..101 {
            s.push(i as f64);
        }
        assert_eq!(s.median(), 50.0);
        assert_eq!(s.quantile(0.0), 0.0);
        assert_eq!(s.quantile(1.0), 100.0);
        assert!((s.quantile(0.25) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn linreg_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-10);
        assert!((b - 2.0).abs() < 1e-10);
        assert!((r2 - 1.0).abs() < 1e-10);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.9);
        for _ in 0..500 {
            e.push(5.0);
        }
        assert!((e.get().unwrap() - 5.0).abs() < 1e-6);
    }
}
