//! Declarative command-line parsing (clap stand-in).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options with
//! defaults, and auto-generated `--help`. Typed accessors parse on demand and
//! report readable errors.

use std::collections::{BTreeMap, BTreeSet};

#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug)]
pub struct Args {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    /// Option/flag names the user actually typed (vs. declared defaults) —
    /// lets config-file layering give explicit CLI args the last word.
    explicit: BTreeSet<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Was this option/flag passed on the command line (not a default)?
    pub fn is_explicit(&self, name: &str) -> bool {
        self.explicit.contains(name)
    }

    pub fn str(&self, name: &str) -> anyhow::Result<String> {
        self.get(name)
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }

    pub fn parse<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))?;
        raw.parse::<T>()
            .map_err(|e| anyhow::anyhow!("--{name}={raw}: {e}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Comma-separated list of T.
    pub fn list<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<Vec<T>>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.str(name)?;
        raw.split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse::<T>()
                    .map_err(|e| anyhow::anyhow!("--{name} item '{s}': {e}"))
            })
            .collect()
    }
}

/// Top-level application: a set of subcommands.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl App {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, commands: Vec::new() }
    }

    pub fn command(mut self, c: Command) -> Self {
        self.commands.push(c);
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&format!("  {:<22} {}\n", c.name, c.about));
        }
        s.push_str("\nRun `<command> --help` for per-command options.\n");
        s
    }

    pub fn command_usage(&self, c: &Command) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.name, c.name, c.about);
        for o in &c.opts {
            let kind = if o.is_flag {
                String::new()
            } else if let Some(d) = o.default {
                format!(" <value> (default: {d})")
            } else {
                " <value> (required)".to_string()
            };
            s.push_str(&format!("  --{:<24} {}{}\n", format!("{}{}", o.name, kind), o.help, ""));
        }
        s
    }

    /// Parse `argv[1..]`. Returns `Err(msg)` where `msg` is the full usage
    /// text for help requests or a diagnostic for bad input.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            return Err(self.usage());
        }
        let cmd_name = &argv[0];
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| format!("unknown command '{cmd_name}'\n\n{}", self.usage()))?;

        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        let mut explicit = BTreeSet::new();
        let mut positional = Vec::new();
        for o in &cmd.opts {
            if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }

        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.command_usage(cmd));
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = cmd
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key} for '{cmd_name}'\n\n{}", self.command_usage(cmd)))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    flags.insert(key.to_string(), true);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} expects a value"))?
                        }
                    };
                    values.insert(key.to_string(), v);
                }
                explicit.insert(key.to_string());
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }

        // Required options present?
        for o in &cmd.opts {
            if !o.is_flag && o.default.is_none() && !values.contains_key(o.name) {
                return Err(format!("missing required option --{}\n\n{}", o.name, self.command_usage(cmd)));
            }
        }

        Ok(Args { command: cmd_name.clone(), values, flags, explicit, positional })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("soap-lab", "test").command(
            Command::new("train", "train a model")
                .opt("steps", "100", "number of steps")
                .opt("optimizer", "soap", "optimizer name")
                .req("out", "output path")
                .flag("verbose", "log more"),
        )
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_overrides() {
        let a = app()
            .parse(&argv(&["train", "--steps", "250", "--out=/tmp/x", "--verbose"]))
            .unwrap();
        assert_eq!(a.parse::<u32>("steps").unwrap(), 250);
        assert_eq!(a.get("optimizer"), Some("soap"));
        assert_eq!(a.get("out"), Some("/tmp/x"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn explicit_tracks_typed_options_only() {
        let a = app()
            .parse(&argv(&["train", "--steps", "250", "--out=/tmp/x", "--verbose"]))
            .unwrap();
        assert!(a.is_explicit("steps"));
        assert!(a.is_explicit("out"));
        assert!(a.is_explicit("verbose"));
        assert!(!a.is_explicit("optimizer"), "defaults are not explicit");
    }

    #[test]
    fn missing_required_errors() {
        assert!(app().parse(&argv(&["train"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(app().parse(&argv(&["train", "--out", "x", "--bogus", "1"])).is_err());
    }

    #[test]
    fn unknown_command_errors() {
        assert!(app().parse(&argv(&["zzz"])).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = app()
            .parse(&argv(&["train", "--out", "x", "--steps", "1,2,4"]))
            .unwrap();
        // `steps` reused as a list for this test.
        assert_eq!(a.list::<u32>("steps").unwrap(), vec![1, 2, 4]);
    }

    #[test]
    fn help_is_err_with_usage() {
        let e = app().parse(&argv(&["--help"])).unwrap_err();
        assert!(e.contains("COMMANDS"));
        let e = app().parse(&argv(&["train", "--help"])).unwrap_err();
        assert!(e.contains("OPTIONS"));
    }
}
