//! Minimal JSON parser + emitter.
//!
//! serde is not in the offline registry, so the artifact `manifest.json`
//! (written by `python/compile/aot.py`), metrics dumps, and experiment result
//! files go through this module. It supports the full JSON grammar minus
//! exotic number forms; numbers are held as `f64` (adequate: the manifest
//! stores shapes and names, metrics store floats/ints below 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -----------------------------------------------
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["k"]` associative access; returns Null for misses so lookups chain.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---- construction helpers ------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are rare in our files; map lone
                            // surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": null, "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").as_str(), Some("x\ny"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("d").as_bool(), Some(true));
        let re = Json::parse(&v.dump()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn nested_roundtrip() {
        let v = Json::obj(vec![
            ("shapes", Json::arr([Json::num(128), Json::num(512)])),
            (
                "meta",
                Json::obj(vec![("name", Json::str("soap_update_128x512"))]),
            ),
        ]);
        let re = Json::parse(&v.pretty()).unwrap();
        assert_eq!(re, v);
        assert_eq!(
            re.get("meta").get("name").as_str(),
            Some("soap_update_128x512")
        );
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str(), Some("éA"));
    }

    #[test]
    fn integers_dump_without_decimal() {
        assert_eq!(Json::num(42.0).dump(), "42");
        assert_eq!(Json::num(2.5).dump(), "2.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::Arr(vec![]).dump(), "[]");
    }
}
