//! Fixed-size worker thread pool (tokio stand-in for our workloads).
//!
//! The coordinator's layer-sharded optimizer updates and the precond
//! module's background refreshes are CPU-bound, so a plain thread pool with
//! an mpsc work queue is the right substrate: `scope_execute` fans a set of
//! closures out to the workers and joins them, propagating panics; `submit`
//! is the fire-and-forget entry the refresh service uses. Work items are
//! `FnOnce` boxed closures; results flow back through a channel.
//!
//! Shutdown is deterministic: `Drop` enqueues one `Shutdown` message per
//! worker (FIFO behind any pending jobs, so queued work drains first) and
//! joins every handle — no leaked `soap-worker-*` threads. The sender side
//! sits behind a `Mutex` so the pool is `Sync` (shareable via `Arc` across
//! shard workers) on every toolchain, independent of whether `mpsc::Sender`
//! implements `Sync`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads consuming from a shared queue.
pub struct ThreadPool {
    tx: Mutex<Sender<Msg>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = channel::<Msg>();
        // Workers share the receiver; the constructor's reference is dropped
        // here — only `tx` (for submission) and the worker handles remain.
        let rx_shared = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(size);
        for id in 0..size {
            let rx = Arc::clone(&rx_shared);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("soap-worker-{id}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Self { tx: Mutex::new(tx), workers, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a single fire-and-forget job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .lock()
            .unwrap()
            .send(Msg::Run(Box::new(f)))
            .expect("pool alive");
    }

    /// Run `jobs` across the pool and collect their results **in input
    /// order**; blocks until all complete. Panics in jobs are surfaced.
    pub fn scope_execute<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        let (rtx, rrx) = channel::<(usize, std::thread::Result<T>)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let rtx = rtx.clone();
            self.submit(move || {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, res) = rrx.recv().expect("worker result");
            match res {
                Ok(v) => slots[i] = Some(v),
                Err(p) => std::panic::resume_unwind(p),
            }
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn par_map<I, T, F>(&self, items: Vec<I>, f: F) -> Vec<T>
    where
        I: Send + 'static,
        T: Send + 'static,
        F: Fn(I) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let jobs: Vec<_> = items
            .into_iter()
            .map(|it| {
                let f = Arc::clone(&f);
                move || f(it)
            })
            .collect();
        self.scope_execute(jobs)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // One Shutdown per worker, queued FIFO behind pending jobs so the
        // queue drains before the workers exit; then join every handle.
        {
            let tx = self.tx.lock().unwrap();
            for _ in 0..self.workers.len() {
                let _ = tx.send(Msg::Shutdown);
            }
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A monotonically increasing counter shared across threads (metrics helper).
#[derive(Default)]
pub struct SharedCounter(AtomicUsize);

impl SharedCounter {
    pub fn new() -> Self {
        Self(AtomicUsize::new(0))
    }
    pub fn add(&self, n: usize) -> usize {
        self.0.fetch_add(n, Ordering::Relaxed)
    }
    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.par_map((0..100).collect::<Vec<i64>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn scope_execute_runs_all() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(SharedCounter::new());
        let jobs: Vec<_> = (0..50)
            .map(|_| {
                let c = Arc::clone(&counter);
                move || {
                    c.add(1);
                    1usize
                }
            })
            .collect();
        let results = pool.scope_execute(jobs);
        assert_eq!(results.len(), 50);
        assert_eq!(counter.get(), 50);
    }

    #[test]
    #[should_panic]
    fn panic_propagates() {
        let pool = ThreadPool::new(2);
        pool.scope_execute(vec![
            Box::new(|| 1usize) as Box<dyn FnOnce() -> usize + Send>,
            Box::new(|| panic!("boom")),
        ]);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
        });
        drop(pool); // must not deadlock
    }

    #[test]
    fn drop_drains_queue_then_joins_every_worker() {
        // Each queued job holds a clone of `alive`. After drop() returns
        // (which joins every worker), only our reference may remain — proof
        // that the queue drained and every job closure was consumed before
        // the workers shut down.
        let alive = Arc::new(());
        let ran = Arc::new(SharedCounter::new());
        let pool = ThreadPool::new(3);
        for _ in 0..pool.size() {
            let keep = Arc::clone(&alive);
            pool.submit(move || {
                let _keep = keep;
                std::thread::sleep(std::time::Duration::from_millis(5));
            });
        }
        for _ in 0..20 {
            let c = Arc::clone(&ran);
            pool.submit(move || {
                c.add(1);
            });
        }
        drop(pool);
        assert_eq!(ran.get(), 20, "queued jobs must drain before shutdown");
        assert_eq!(Arc::strong_count(&alive), 1, "a soap-worker-* thread leaked");
    }

    #[test]
    fn pool_is_shareable_across_threads() {
        // Compile-time Send+Sync guarantee (the refresh service shares the
        // pool via Arc from shard worker threads) plus a smoke use.
        fn assert_sync<T: Send + Sync>(_: &T) {}
        let pool = Arc::new(ThreadPool::new(2));
        assert_sync(&*pool);
        let c = Arc::new(SharedCounter::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = Arc::clone(&pool);
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let c2 = Arc::clone(&c);
                pool.submit(move || {
                    c2.add(1);
                });
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Drop the pool (drains the queue) by unwrapping the Arc.
        drop(Arc::try_unwrap(pool).ok());
        assert_eq!(c.get(), 4);
    }

    #[test]
    fn work_actually_parallel() {
        // 4 workers × 50 ms sleep should take well under 4×50 ms total.
        let pool = ThreadPool::new(4);
        let t0 = std::time::Instant::now();
        pool.par_map(vec![(); 4], |_| {
            std::thread::sleep(std::time::Duration::from_millis(50))
        });
        assert!(t0.elapsed() < std::time::Duration::from_millis(190));
    }
}
