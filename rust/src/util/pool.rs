//! Fixed-size worker thread pool (tokio stand-in for our workloads).
//!
//! The coordinator's layer-sharded optimizer updates, the precond module's
//! background refreshes, and the linalg parallel GEMM driver are CPU-bound,
//! so a plain thread pool is the right substrate: `scope_execute` fans a set
//! of closures out to the workers and joins them, propagating panics;
//! `submit` is the fire-and-forget entry the refresh service uses;
//! `scope_borrowed` runs *borrowing* closures (the GEMM driver hands out
//! disjoint `&mut` row chunks of one output matrix).
//!
//! Dispatch is **per-worker channels with round-robin assignment**: each
//! worker owns its own mpsc `Receiver` and `submit` rotates across the
//! senders. The previous design funneled every dequeue through one
//! `Mutex<Receiver>`, which serializes workers at 8+ threads exactly when
//! the row-partitioned GEMM fan-out wants them all running — per-worker
//! queues make the dequeue path lock-free (the submit side keeps a short
//! `Mutex` critical section so the pool stays `Sync` on every toolchain,
//! independent of whether `mpsc::Sender` implements `Sync`). The trade-off
//! is load balance: round-robin is not work-conserving, so a long job
//! delays jobs queued behind it on the same worker while others idle. The
//! GEMM fan-out is uniform (equal row chunks) and unaffected; refresh jobs
//! scale with layer dim³ and CAN collide on one queue — tolerable because
//! `BasisHandle::try_begin_refresh` sheds refreshes rather than queueing a
//! backlog, and a late basis only adds staleness the optimizer already
//! tolerates. If per-layer heterogeneity ever dominates, work stealing (or
//! a shared queue for `submit` only) is the next step.
//!
//! Shutdown is deterministic: `Drop` enqueues one `Shutdown` message per
//! worker (FIFO behind that worker's pending jobs, so queued work drains
//! first) and joins every handle — no leaked `soap-worker-*` threads.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Per-pool utilization counters. Workers record into these only while
/// telemetry is enabled (one relaxed-load check per job otherwise), so the
/// disabled cost is a branch — no clock read, no contention.
#[derive(Default)]
pub struct PoolStats {
    jobs: AtomicU64,
    busy_ns: AtomicU64,
}

impl PoolStats {
    /// `(jobs executed, cumulative busy seconds)` across all workers.
    pub fn snapshot(&self) -> (u64, f64) {
        (
            self.jobs.load(Ordering::Relaxed),
            self.busy_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        )
    }
}

/// A fixed pool of worker threads, each consuming from its own queue.
pub struct ThreadPool {
    txs: Mutex<Vec<Sender<Msg>>>,
    next: AtomicUsize,
    workers: Vec<JoinHandle<()>>,
    size: usize,
    stats: Arc<PoolStats>,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let stats = Arc::new(PoolStats::default());
        let mut txs = Vec::with_capacity(size);
        let mut workers = Vec::with_capacity(size);
        for id in 0..size {
            let (tx, rx) = channel::<Msg>();
            txs.push(tx);
            let stats = Arc::clone(&stats);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("soap-worker-{id}"))
                    .spawn(move || loop {
                        match rx.recv() {
                            // A panicking fire-and-forget job must not take
                            // the worker (and, with per-worker queues, every
                            // job behind it + the round-robin sender) down
                            // with it. The scoped entries propagate panics
                            // to the caller through their token channels.
                            Ok(Msg::Run(job)) => {
                                if crate::telemetry::enabled() {
                                    let t0 = std::time::Instant::now();
                                    let _ = std::panic::catch_unwind(
                                        std::panic::AssertUnwindSafe(job),
                                    );
                                    stats.jobs.fetch_add(1, Ordering::Relaxed);
                                    stats.busy_ns.fetch_add(
                                        t0.elapsed().as_nanos() as u64,
                                        Ordering::Relaxed,
                                    );
                                } else {
                                    let _ = std::panic::catch_unwind(
                                        std::panic::AssertUnwindSafe(job),
                                    );
                                }
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Self { txs: Mutex::new(txs), next: AtomicUsize::new(0), workers, size, stats }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Utilization snapshot: `(jobs executed, cumulative busy seconds)`.
    /// Only advances while telemetry is enabled.
    pub fn stats(&self) -> (u64, f64) {
        self.stats.snapshot()
    }

    /// Submit a single fire-and-forget job (round-robin worker assignment).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.submit_boxed(Box::new(f));
    }

    fn submit_boxed(&self, job: Job) {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.size;
        self.txs.lock().unwrap()[i].send(Msg::Run(job)).expect("pool alive");
    }

    /// Run `jobs` across the pool and collect their results **in input
    /// order**; blocks until all complete. Panics in jobs are surfaced
    /// (after every job has finished, so sibling jobs never outlive the
    /// call).
    pub fn scope_execute<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        let (rtx, rrx) = channel::<(usize, std::thread::Result<T>)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let rtx = rtx.clone();
            self.submit(move || {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut first_panic = None;
        for _ in 0..n {
            let (i, res) = rrx.recv().expect("worker result");
            match res {
                Ok(v) => slots[i] = Some(v),
                Err(p) => {
                    if first_panic.is_none() {
                        first_panic = Some(p);
                    }
                }
            }
        }
        if let Some(p) = first_panic {
            std::panic::resume_unwind(p);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }

    /// Run closures that **borrow** from the caller's stack (e.g. disjoint
    /// `&mut` row chunks of one matrix) across the pool; blocks until every
    /// job has finished, then propagates the first panic if any.
    ///
    /// This is the scoped entry point the parallel GEMM driver uses: the
    /// borrowed data outlives the call because the call does not return (or
    /// unwind) until every submitted job has dropped its completion sender.
    ///
    /// Deadlock hazard (as with any blocking scope on a fixed pool): do NOT
    /// call this — or `scope_execute`/`par_map` — from a job running on the
    /// SAME pool; round-robin can queue a child job behind the blocked
    /// parent. Current callers can't nest: the GEMM drivers use the static
    /// linalg pool, the refresh service its own pool.
    pub fn scope_borrowed<'scope>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        let n = jobs.len();
        if n == 0 {
            return;
        }
        let (rtx, rrx) = channel::<std::thread::Result<()>>();
        // Unwind guard: if anything below panics after jobs were submitted
        // (poisoned submit mutex, a closed worker channel), lifetime-erased
        // jobs may still be running against this frame's borrows. Dropping
        // the guard first drops the original sender it owns (so recv can
        // observe disconnection), then blocks until every job's sender
        // clone is gone — i.e. every submitted job has finished — so memory
        // safety never depends on the happy path reaching its receive loop.
        struct DrainOnDrop {
            rx: std::sync::mpsc::Receiver<std::thread::Result<()>>,
            tx: Option<Sender<std::thread::Result<()>>>,
        }
        impl Drop for DrainOnDrop {
            fn drop(&mut self) {
                drop(self.tx.take());
                while self.rx.recv().is_ok() {}
            }
        }
        let mut guard = DrainOnDrop { rx: rrx, tx: Some(rtx) };
        for job in jobs {
            // SAFETY: lifetime erasure only. Every job owns a clone of the
            // result sender and drops it when it finishes (catch_unwind
            // makes the send-then-drop unconditional); both the receive
            // loop below and the `DrainOnDrop` unwind path block until all
            // clones are gone, so no job can run, or be alive, after the
            // 'scope borrows end — whether this function returns or
            // unwinds.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
            let rtx = guard.tx.as_ref().expect("sender held until submit loop ends").clone();
            self.submit_boxed(Box::new(move || {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                let _ = rtx.send(out);
            }));
        }
        drop(guard.tx.take());
        let mut first_panic = None;
        for _ in 0..n {
            match guard.rx.recv().expect("worker result") {
                Ok(()) => {}
                Err(p) => {
                    if first_panic.is_none() {
                        first_panic = Some(p);
                    }
                }
            }
        }
        if let Some(p) = first_panic {
            std::panic::resume_unwind(p);
        }
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn par_map<I, T, F>(&self, items: Vec<I>, f: F) -> Vec<T>
    where
        I: Send + 'static,
        T: Send + 'static,
        F: Fn(I) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let jobs: Vec<_> = items
            .into_iter()
            .map(|it| {
                let f = Arc::clone(&f);
                move || f(it)
            })
            .collect();
        self.scope_execute(jobs)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // One Shutdown per worker, queued FIFO behind that worker's pending
        // jobs so every queue drains before its worker exits; then join
        // every handle.
        {
            let txs = self.txs.lock().unwrap();
            for tx in txs.iter() {
                let _ = tx.send(Msg::Shutdown);
            }
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A monotonically increasing counter shared across threads (metrics helper).
#[derive(Default)]
pub struct SharedCounter(AtomicUsize);

impl SharedCounter {
    pub fn new() -> Self {
        Self(AtomicUsize::new(0))
    }
    pub fn add(&self, n: usize) -> usize {
        self.0.fetch_add(n, Ordering::Relaxed)
    }
    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.par_map((0..100).collect::<Vec<i64>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn scope_execute_runs_all() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(SharedCounter::new());
        let jobs: Vec<_> = (0..50)
            .map(|_| {
                let c = Arc::clone(&counter);
                move || {
                    c.add(1);
                    1usize
                }
            })
            .collect();
        let results = pool.scope_execute(jobs);
        assert_eq!(results.len(), 50);
        assert_eq!(counter.get(), 50);
    }

    #[test]
    #[should_panic]
    fn panic_propagates() {
        let pool = ThreadPool::new(2);
        pool.scope_execute(vec![
            Box::new(|| 1usize) as Box<dyn FnOnce() -> usize + Send>,
            Box::new(|| panic!("boom")),
        ]);
    }

    #[test]
    fn panicking_submit_job_does_not_kill_worker() {
        // Fire-and-forget panics are contained in the worker loop; with
        // per-worker queues a dead worker would strand its queue and break
        // every size-th later submit.
        let pool = ThreadPool::new(2);
        for _ in 0..4 {
            pool.submit(|| panic!("fire-and-forget failure"));
        }
        let out = pool.par_map(vec![1i64, 2, 3, 4, 5, 6], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
        });
        drop(pool); // must not deadlock
    }

    #[test]
    fn drop_drains_queue_then_joins_every_worker() {
        // Each queued job holds a clone of `alive`. After drop() returns
        // (which joins every worker), only our reference may remain — proof
        // that every per-worker queue drained and every job closure was
        // consumed before the workers shut down.
        let alive = Arc::new(());
        let ran = Arc::new(SharedCounter::new());
        let pool = ThreadPool::new(3);
        for _ in 0..pool.size() {
            let keep = Arc::clone(&alive);
            pool.submit(move || {
                let _keep = keep;
                std::thread::sleep(std::time::Duration::from_millis(5));
            });
        }
        for _ in 0..20 {
            let c = Arc::clone(&ran);
            pool.submit(move || {
                c.add(1);
            });
        }
        drop(pool);
        assert_eq!(ran.get(), 20, "queued jobs must drain before shutdown");
        assert_eq!(Arc::strong_count(&alive), 1, "a soap-worker-* thread leaked");
    }

    #[test]
    fn pool_is_shareable_across_threads() {
        // Compile-time Send+Sync guarantee (the refresh service shares the
        // pool via Arc from shard worker threads) plus a smoke use.
        fn assert_sync<T: Send + Sync>(_: &T) {}
        let pool = Arc::new(ThreadPool::new(2));
        assert_sync(&*pool);
        let c = Arc::new(SharedCounter::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = Arc::clone(&pool);
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let c2 = Arc::clone(&c);
                pool.submit(move || {
                    c2.add(1);
                });
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Drop the pool (drains the queues) by unwrapping the Arc.
        drop(Arc::try_unwrap(pool).ok());
        assert_eq!(c.get(), 4);
    }

    #[test]
    fn work_actually_parallel() {
        // 4 workers × 50 ms sleep should take well under 4×50 ms total.
        let pool = ThreadPool::new(4);
        let t0 = std::time::Instant::now();
        pool.par_map(vec![(); 4], |_| {
            std::thread::sleep(std::time::Duration::from_millis(50))
        });
        assert!(t0.elapsed() < std::time::Duration::from_millis(190));
    }

    #[test]
    fn round_robin_touches_every_worker() {
        // `size` jobs submitted back-to-back land on `size` distinct workers
        // (round-robin), so they all run concurrently: a rendezvous barrier
        // completes only if every worker got exactly one job. Run under a
        // watchdog — a dispatch regression (two jobs on one queue) would
        // otherwise deadlock the barrier and hang the suite instead of
        // failing.
        let (done_tx, done_rx) = channel::<()>();
        let runner = std::thread::spawn(move || {
            let pool = ThreadPool::new(4);
            let barrier = Arc::new(std::sync::Barrier::new(4));
            let jobs: Vec<_> = (0..4)
                .map(|_| {
                    let b = Arc::clone(&barrier);
                    move || {
                        b.wait();
                    }
                })
                .collect();
            pool.scope_execute(jobs);
            let _ = done_tx.send(());
        });
        match done_rx.recv_timeout(std::time::Duration::from_secs(5)) {
            Ok(()) => runner.join().unwrap(),
            // Leak the wedged runner thread: joining it would hang too.
            Err(_) => panic!("round-robin dispatch failed to reach all workers (barrier stuck)"),
        }
    }

    #[test]
    fn pool_stats_track_jobs_only_while_telemetry_enabled() {
        let _g = crate::telemetry::trace::test_lock();
        let pool = ThreadPool::new(2);
        pool.par_map(vec![1u32, 2, 3], |x| x);
        assert_eq!(pool.stats().0, 0, "disabled telemetry must not count jobs");
        crate::telemetry::set_enabled(true);
        pool.par_map(vec![1u32, 2, 3, 4], |x| x);
        crate::telemetry::set_enabled(false);
        let (jobs, busy_s) = pool.stats();
        assert_eq!(jobs, 4);
        assert!(busy_s >= 0.0);
    }

    #[test]
    fn scope_borrowed_mutates_disjoint_chunks() {
        let pool = ThreadPool::new(4);
        let mut v = vec![0u32; 103];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = v
            .chunks_mut(25)
            .map(|chunk| {
                Box::new(move || {
                    for x in chunk.iter_mut() {
                        *x += 1;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope_borrowed(jobs);
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    #[should_panic]
    fn scope_borrowed_propagates_panics_after_completion() {
        let pool = ThreadPool::new(2);
        let data = [1u32, 2, 3];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| {
                let _ = data[0];
            }),
            Box::new(|| panic!("synthetic kernel failure")),
        ];
        pool.scope_borrowed(jobs);
    }
}
