//! `soap-lab` CLI — the launcher. Every command rides the typed
//! `session::TrainSession` builder; `main.rs` only parses options and
//! prints summaries.
//!
//! ```text
//! soap-lab train      --model small --optimizer soap --lr 3.16e-3 …
//! soap-lab train      --model nplm --backend serial --save run.ckpt
//! soap-lab train      --config run.cfg --resume run.ckpt --steps 400
//! soap-lab sweep      --spec examples/sweep_nplm_tiny.json --max-mem-bytes 268435456
//! soap-lab sweep      --spec sweep.json --out-dir sweep-out --resume-sweep
//! soap-lab sweep-lr   --model nano  --optimizer soap --steps 150
//! soap-lab inspect    --artifacts artifacts
//! soap-lab corpus     --vocab 512
//! ```

use std::time::Duration;

use soap_lab::config::RunConfig;
use soap_lab::data::{CorpusSpec, SyntheticCorpus};
use soap_lab::dist::{spawn_workers, ChildGuard};
use soap_lab::runtime::Engine;
use soap_lab::session::{Backend, DistEndpoint, DistOptions};
use soap_lab::sweep::{run_sweep, JobSpec, SweepOptions, SweepSpec};
use soap_lab::util::cli::{App, Command};

fn app() -> App {
    App::new("soap-lab", "SOAP optimizer reproduction (rust + JAX + Pallas)")
        .command(
            Command::new("train", "train an LM through the session builder")
                .opt(
                    "model",
                    "nano",
                    "artifact manifest config, or a native model (nplm|nplm-tiny|nplm-conv)",
                )
                .opt(
                    "optimizer",
                    "soap",
                    "adamw|adafactor|shampoo|soap|galore, or a composition \
                     basis=<identity|eigen[:one-sided|:two-sided]|svd>,inner=<adam|adafactor|shampoo>[,graft=<adam|none>]",
                )
                .opt(
                    "backend",
                    "sharded",
                    "optimizer executor: serial|sharded|pjrt|distributed",
                )
                .opt("lr", "0.00316", "peak learning rate")
                .opt("steps", "200", "TOTAL training steps (a resumed run continues to this total)")
                .opt("warmup", "0", "warmup steps (0 = constant LR)")
                .opt("seed", "0", "data/init seed")
                .opt(
                    "precond-freq",
                    "10",
                    "preconditioning frequency: a number, or a schedule f@start,f@start,…",
                )
                .opt("grad-accum", "1", "gradient-accumulation microbatches")
                .opt("workers", "4", "optimizer worker threads")
                .opt("refresh-workers", "2", "async refresh service worker threads")
                .opt("refresh-method", "", "qr|eigh (named form of --refresh-eigh)")
                .opt("refresh-mode", "", "inline|async (named form of --async-refresh)")
                .opt(
                    "max-precond-dim",
                    "4096",
                    "dims above this keep Q=identity (per mode for rank-3+ tensors; == is preconditioned)",
                )
                .opt(
                    "merge-dims",
                    "0",
                    "rank-3+ tensors: merge adjacent modes while the product stays <= this (0 = off)",
                )
                .opt(
                    "adam-warmup",
                    "0",
                    "steps of pure inner-optimizer updates before any eigenbasis starts (0 = off)",
                )
                .opt(
                    "precond-warmup",
                    "0",
                    "refresh the eigenbasis every step for the first k steps (0 = off)",
                )
                .opt(
                    "state-dtype",
                    "f32",
                    "second-moment storage: f32|bf16 (bf16 halves factor/V state bytes)",
                )
                .opt("ranks", "2", "world size for --backend distributed (self-spawns workers)")
                .opt(
                    "rank",
                    "",
                    "manual-launch worker mode: this process's rank (with --coordinator-addr)",
                )
                .opt(
                    "coordinator-addr",
                    "",
                    "rendezvous address for manually launched distributed ranks",
                )
                .opt(
                    "dist-timeout",
                    "30000",
                    "distributed peer-failure timeout, milliseconds",
                )
                .opt("dist-transport", "tcp", "distributed wire: tcp (mem is API-only)")
                .opt("artifacts", "artifacts", "artifact directory")
                .opt("log-every", "10", "log every k steps (0 = silent)")
                .opt(
                    "metrics-every",
                    "10",
                    "emit a health snapshot every k steps (with --telemetry)",
                )
                .opt(
                    "trace-out",
                    "",
                    "write a Chrome trace-event JSON here at the end (with --telemetry)",
                )
                .opt(
                    "metrics-out",
                    "",
                    "write a Prometheus text snapshot here at the end (with --telemetry)",
                )
                .opt("jsonl-out", "", "stream per-step (and per-health) JSON lines to this file")
                .opt("config", "", "key=value config file (CLI args override it)")
                .opt("save", "", "write a checkpoint here at the end")
                .opt("resume", "", "resume from this checkpoint (restores step + data cursor)")
                .opt(
                    "guard",
                    "skip-step",
                    "non-finite gradient/update response: off|skip-step|clip[:max]|abort",
                )
                .opt(
                    "fault-plan",
                    "",
                    "seeded fault-injection plan for chaos testing (see README)",
                )
                .opt(
                    "auto-resume",
                    "0",
                    "distributed: relaunch from the abort checkpoint up to N times on peer failure",
                )
                .opt(
                    "fault-attempt",
                    "0",
                    "internal: auto-resume relaunch counter (disarms one-shot injected faults)",
                )
                .flag("dump-config", "print the resolved config as a loadable file and exit")
                .flag(
                    "telemetry",
                    "enable span tracing + health metrics (see README: Observability)",
                )
                .flag("one-sided", "SOAP one-sided variant (§7.1)")
                .flag("factorized", "SOAP factorized variant (§7.2.1)")
                .flag(
                    "precondition-1d",
                    "rotate 1-D params too instead of the paper's Adam fallback (§7.3)",
                )
                .flag("refresh-eigh", "use full eigh refresh (Fig 7 right)")
                .flag("async-refresh", "run eigenbasis refreshes on the background service (off the hot path)")
                .flag("pjrt-optimizer", "legacy alias for --backend pjrt"),
        )
        .command(
            Command::new("sweep", "run a declarative sweep of concurrent training jobs")
                .req("spec", "sweep spec JSON (base config + grid axes; see README)")
                .opt("out-dir", "sweep-out", "directory for manifest/journal/metrics/results")
                .opt(
                    "max-mem-bytes",
                    "0",
                    "global memory budget over running jobs' estimated footprints (0 = unlimited)",
                )
                .opt("max-concurrency", "2", "maximum concurrently-running jobs")
                .opt(
                    "ckpt-every",
                    "0",
                    "checkpoint each running job every k of its steps (0 = only when halting)",
                )
                .opt(
                    "halt-after-steps",
                    "0",
                    "stop the sweep after this many steps summed across jobs (0 = run to completion)",
                )
                .opt("workers", "", "optimizer worker threads per job (default: the spec's `workers`)")
                .opt("artifacts", "", "artifact directory (default: the spec's `artifacts`)")
                .opt("metrics-out", "", "write a Prometheus text snapshot here at the end")
                .flag("resume-sweep", "resume an interrupted sweep in --out-dir")
                .flag("telemetry", "enable telemetry for every job (the seam is process-global)"),
        )
        .command(
            Command::new("sweep-lr", "learning-rate sweep (Appendix A grid)")
                .opt("model", "nano", "model config")
                .opt("optimizer", "soap", "optimizer")
                .opt("backend", "sharded", "optimizer executor: serial|sharded|pjrt")
                .opt("steps", "150", "steps per point")
                .opt("seed", "0", "seed")
                .opt("precond-freq", "10", "preconditioning frequency")
                .opt("artifacts", "artifacts", "artifact directory")
                .opt("out-dir", "sweep-lr-out", "sweep output directory (manifest/journal/results)")
                .opt("max-concurrency", "2", "concurrently-running points"),
        )
        .command(
            Command::new("inspect", "print the artifact manifest summary")
                .opt("artifacts", "artifacts", "artifact directory"),
        )
        .command(
            Command::new("corpus", "print synthetic-corpus statistics")
                .opt("vocab", "512", "vocabulary size")
                .opt("alpha", "1.2", "Zipf exponent")
                .opt("seed", "0", "seed"),
        )
}

fn cmd_train(args: &soap_lab::util::cli::Args) -> anyhow::Result<()> {
    let mut rc = RunConfig::from_args(args)?;
    if args.flag("dump-config") {
        print!("{}", rc.dump());
        return Ok(());
    }
    // Distributed roles. A worker rank (--rank N>0, spawned by the
    // coordinator or launched manually) is quiet: rank 0 owns the banner,
    // the step log, the summary, the checkpoint, and the metrics files.
    // Each rank still writes its OWN trace file (the recorder is
    // per-process), so workers suffix theirs with the rank.
    let worker_rank = match rc.backend {
        Backend::Distributed { .. } => rc.dist_rank.filter(|&r| r > 0),
        _ => None,
    };
    let quiet = worker_rank.is_some();
    if let Some(r) = worker_rank {
        rc.log_every = 0;
        rc.save = None;
        rc.jsonl_out = None;
        rc.metrics_out = None;
        rc.trace_out = rc.trace_out.take().map(|p| format!("{p}.rank{r}"));
    }
    if !quiet {
        println!(
            "train: model={} optimizer={} backend={} lr={} steps={} f={} accum={} refresh={}",
            rc.model,
            rc.optimizer.name(),
            rc.backend.name(),
            rc.lr,
            rc.steps,
            rc.precond_freq,
            rc.grad_accum,
            if rc.async_refresh { "async" } else { "inline" }
        );
    }
    // Auto-resume: the self-spawn coordinator retries a failed distributed
    // run up to --auto-resume times. Each failed attempt leaves an abort
    // checkpoint behind (rank 0 exports without collectives, so a dead peer
    // cannot hang the save); the retry resumes every rank from it with
    // --fault-attempt bumped, which disarms one-shot injected faults
    // (crash-rank, eigh-fail, …) so chaos runs converge instead of
    // re-crashing forever. Worker ranks never loop — the coordinator
    // respawns them with the resume args appended (the CLI keeps the last
    // occurrence of a repeated option, so the append is authoritative).
    let retries = if worker_rank.is_none() { rc.auto_resume } else { 0 };
    let abort_ckpt = rc.save.clone().unwrap_or_else(|| "soap-abort.ckpt".to_string());
    let mut extra: Vec<String> = Vec::new();
    loop {
        let err = match run_attempt(&rc, worker_rank, quiet, &extra, &abort_ckpt) {
            Ok(()) => return Ok(()),
            Err(e) => e,
        };
        let attempt = rc.fault_attempt + 1;
        if attempt > retries || !std::path::Path::new(&abort_ckpt).exists() {
            return Err(err);
        }
        eprintln!("auto-resume {attempt}/{retries}: retrying from {abort_ckpt} after: {err:#}");
        rc.resume = Some(abort_ckpt.clone());
        rc.fault_attempt = attempt;
        extra = vec![
            "--resume".to_string(),
            abort_ckpt.clone(),
            "--fault-attempt".to_string(),
            attempt.to_string(),
        ];
    }
}

fn run_attempt(
    rc: &RunConfig,
    worker_rank: Option<usize>,
    quiet: bool,
    extra_argv: &[String],
    abort_ckpt: &str,
) -> anyhow::Result<()> {
    let mut builder = rc.session_builder()?;
    // Coordinator side of the distributed backend: bind the rendezvous
    // listener BEFORE spawning or building, so workers never dial a
    // not-yet-listening address. Self-spawn mode (no --rank) replays this
    // process's argv into `ranks-1` children with `--rank R
    // --coordinator-addr ADDR` appended; manual mode (--rank 0) binds the
    // user-supplied address and waits for externally launched peers.
    let mut guard: Option<ChildGuard> = None;
    if let Backend::Distributed { ranks, .. } = rc.resolved_backend() {
        if worker_rank.is_none() {
            let bind = match (&rc.dist_rank, &rc.coordinator_addr) {
                (Some(0), Some(addr)) => addr.clone(),
                _ => "127.0.0.1:0".to_string(),
            };
            let listener = std::net::TcpListener::bind(&bind)
                .map_err(|e| anyhow::anyhow!("binding rendezvous listener on {bind}: {e}"))?;
            let addr = listener.local_addr()?.to_string();
            if rc.dist_rank.is_none() {
                let mut argv: Vec<String> = std::env::args().skip(1).collect();
                argv.extend_from_slice(extra_argv);
                guard = Some(spawn_workers(ranks, &addr, &argv)?);
            }
            builder = builder.dist(DistOptions {
                rank: 0,
                ranks,
                timeout: Duration::from_millis(rc.dist_timeout_ms),
                endpoint: DistEndpoint::Tcp { coordinator: addr, listener: Some(listener) },
            });
        }
    }
    // One seam: validation, artifact preflight, and checkpoint resume
    // (params + optimizer state + schedule step + data cursor together)
    // all happen inside build().
    let mut session = builder.build()?;
    if let Some(path) = &rc.jsonl_out {
        let file = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("--jsonl-out {path}: {e}"))?;
        let sink = soap_lab::session::JsonlSink::new(std::io::BufWriter::new(file));
        session.add_sink(Box::new(sink));
    }
    if let Some(path) = &rc.resume {
        if !quiet {
            println!(
                "resumed from {path} at step {} ({} steps remaining)",
                session.current_step(),
                session.total_steps() - session.current_step()
            );
        }
    }

    let log = match session.run() {
        Ok(log) => log,
        Err(e) => {
            // Peer failure (or any mid-run error): leave an atomic abort
            // checkpoint so --auto-resume (or the operator) can restart
            // every rank from the last completed step. Export is
            // collective-free, so a dead peer cannot hang the save.
            if rc.auto_resume > 0 && worker_rank.is_none() {
                match session.save_checkpoint(abort_ckpt) {
                    Ok(()) => eprintln!("abort checkpoint saved to {abort_ckpt}"),
                    Err(se) => eprintln!("abort checkpoint save failed: {se:#}"),
                }
            }
            drop(session); // close this rank's sockets first…
            drop(guard); // …then kill-and-reap workers stuck on dead collectives
            return Err(e);
        }
    };
    if !quiet {
        println!(
            "\nfinal loss {:.4} (tail {:.4})  entropy floor {:.4}",
            log.final_loss(),
            log.tail_loss(20),
            session.entropy_floor()
        );
        println!(
            "throughput {:.0} tok/s   optimizer overhead {:.1}%   state {} bytes   scratch {} bytes",
            log.tokens_per_second(),
            100.0 * log.optimizer_overhead_frac(),
            session.state_bytes(),
            session.scratch_bytes()
        );
    }
    session.wait_refresh_idle(); // count refreshes still in flight at the end
    if !quiet {
        println!(
            "refresh: hot-path {:.3}s  background {:.3}s  mean staleness {:.1} steps  p99 step {:.1}ms",
            log.refresh_seconds_total(),
            session.async_refresh_seconds(),
            log.mean_staleness(),
            1e3 * log.step_time_quantile(0.99),
        );
    }

    if let Some(path) = &rc.save {
        session.save_checkpoint(path)?;
        println!("checkpoint saved to {path}");
    }
    if let Some(path) = &rc.trace_out {
        if !quiet {
            println!("chrome trace written to {path}");
        }
    }
    if let Some(path) = &rc.metrics_out {
        let text = soap_lab::telemetry::metrics::registry().prometheus();
        std::fs::write(path, text)
            .map_err(|e| anyhow::anyhow!("writing metrics snapshot to {path}: {e}"))?;
        println!("metrics snapshot written to {path}");
    }
    // The session (and its sockets) must outlive the workers' final
    // collectives; drop it only after they exit. A worker that died with a
    // nonzero status turns into an error here, AFTER rank 0's own work —
    // its checkpoint, if requested, is already safely on disk.
    drop(session);
    if let Some(g) = guard {
        g.wait_all()?;
    }
    Ok(())
}

fn cmd_sweep(args: &soap_lab::util::cli::Args) -> anyhow::Result<()> {
    let spec_path = args.str("spec")?;
    let text = std::fs::read_to_string(&spec_path)
        .map_err(|e| anyhow::anyhow!("--spec {spec_path}: {e}"))?;
    let mut spec = SweepSpec::parse(&text)?;
    let artifacts = args.str("artifacts")?;
    if !artifacts.is_empty() {
        spec.artifacts_dir = artifacts;
    }
    let workers = args.str("workers")?;
    if !workers.is_empty() {
        spec.workers = workers
            .parse()
            .map_err(|e| anyhow::anyhow!("--workers {workers}: {e}"))?;
    }
    let halt: u64 = args.parse("halt-after-steps")?;
    let opts = SweepOptions {
        out_dir: std::path::PathBuf::from(args.str("out-dir")?),
        max_mem_bytes: args.parse("max-mem-bytes")?,
        max_concurrency: args.parse("max-concurrency")?,
        resume: args.flag("resume-sweep"),
        ckpt_every: args.parse("ckpt-every")?,
        halt_after_steps: if halt == 0 { None } else { Some(halt) },
        workers_per_job: spec.workers,
        telemetry: args.flag("telemetry"),
    };
    println!(
        "sweep '{}': {} jobs, concurrency {}, memory budget {}{}",
        spec.name,
        spec.jobs.len(),
        opts.max_concurrency,
        if opts.max_mem_bytes == 0 {
            "unlimited".to_string()
        } else {
            format!("{} bytes", opts.max_mem_bytes)
        },
        if opts.resume { " (resuming)" } else { "" },
    );
    let outcome = run_sweep(&spec, &opts)?;
    let (mut done, mut failed) = (0usize, 0usize);
    for row in &outcome.rows {
        let id = row.get("job_id").as_str().unwrap_or("?");
        if row.get("status").as_str() == Some("done") {
            done += 1;
            let tail = row.get("tail_loss").as_f64().unwrap_or(f64::NAN);
            println!("  {id}  done    tail loss {tail:.4}");
        } else {
            failed += 1;
            println!(
                "  {id}  failed  {}",
                row.get("error").as_str().unwrap_or("unknown error")
            );
        }
    }
    println!(
        "{done} done, {failed} failed, {} pending; metrics: {}",
        spec.jobs.len() - done - failed,
        outcome.metrics_path.display()
    );
    if outcome.halted {
        println!(
            "sweep halted; continue with: soap-lab sweep --spec {spec_path} --out-dir {} --resume-sweep",
            opts.out_dir.display()
        );
    } else if let Some(path) = &outcome.results_path {
        println!("results written to {}", path.display());
    }
    let metrics_out = args.str("metrics-out")?;
    if !metrics_out.is_empty() {
        let text = soap_lab::telemetry::metrics::registry().prometheus();
        std::fs::write(&metrics_out, text)
            .map_err(|e| anyhow::anyhow!("writing metrics snapshot to {metrics_out}: {e}"))?;
        println!("metrics snapshot written to {metrics_out}");
    }
    Ok(())
}

fn cmd_sweep_lr(args: &soap_lab::util::cli::Args) -> anyhow::Result<()> {
    let rc = RunConfig::from_args(args)?;
    if matches!(rc.backend, Backend::Distributed { .. }) {
        anyhow::bail!(
            "sweep-lr drives in-process sessions (use --backend serial|sharded|pjrt); \
             for orchestrated multi-job grids use `soap-lab sweep --spec <file>`, which \
             schedules concurrent in-process jobs under a memory budget"
        );
    }
    println!("lr sweep for {} on {}", rc.optimizer.name(), rc.model);
    // The Appendix A grid as an explicit job list through the sweep
    // orchestrator: same sessions as before, but scheduled concurrently
    // and journaled/resumable like any other sweep.
    let jobs: Vec<JobSpec> = soap_lab::config::DEFAULT_LRS
        .iter()
        .enumerate()
        .map(|(i, &lr)| {
            let mut job = JobSpec::new(format!("lr{i:02}"), &rc.model, rc.optimizer, rc.steps)
                .with_hyper(rc.hyper())
                .with_lr(lr)
                .with_seed(rc.seed)
                .constant_lr(rc.warmup == 0)
                .with_assign("lr", format!("{lr}"));
            job.backend = Some(rc.backend);
            job.grad_accum = rc.grad_accum;
            job
        })
        .collect();
    let mut spec = SweepSpec::from_jobs("sweep-lr", jobs);
    spec.artifacts_dir = rc.artifacts_dir.clone();
    spec.workers = rc.workers;
    let opts = SweepOptions {
        out_dir: std::path::PathBuf::from(args.str("out-dir")?),
        max_concurrency: args.parse("max-concurrency")?,
        workers_per_job: rc.workers,
        ..SweepOptions::default()
    };
    let outcome = run_sweep(&spec, &opts)?;
    let mut best: Option<(f32, f32)> = None;
    for (i, &lr) in soap_lab::config::DEFAULT_LRS.iter().enumerate() {
        let Some(row) = outcome.row(&format!("lr{i:02}")) else { continue };
        match row.get("tail_loss").as_f64() {
            Some(tail) => {
                let tail = tail as f32;
                println!("  lr {lr:>9.5}  tail loss {tail:.4}");
                if tail.is_finite() && best.map(|(_, b)| tail < b).unwrap_or(true) {
                    best = Some((lr, tail));
                }
            }
            None => println!(
                "  lr {lr:>9.5}  failed: {}",
                row.get("error").as_str().unwrap_or("unknown error")
            ),
        }
    }
    if let Some((lr, loss)) = best {
        println!("best: lr {lr} (loss {loss:.4})");
    }
    Ok(())
}

fn cmd_inspect(args: &soap_lab::util::cli::Args) -> anyhow::Result<()> {
    let engine = Engine::load(args.str("artifacts")?)?;
    println!("platform: {}", engine.platform());
    println!("baked hyper: {:?}", engine.manifest.hyper);
    for (name, cfg) in &engine.manifest.configs {
        println!(
            "config {name}: vocab={} dim={} depth={} seq={} batch={} params={} ({} non-embedding)",
            cfg.vocab, cfg.dim, cfg.depth, cfg.seq, cfg.batch, cfg.num_params,
            cfg.non_embedding_params
        );
    }
    println!("{} artifacts:", engine.manifest.artifacts.len());
    for key in engine.manifest.artifacts.keys() {
        println!("  {key}");
    }
    Ok(())
}

fn cmd_corpus(args: &soap_lab::util::cli::Args) -> anyhow::Result<()> {
    let spec = CorpusSpec {
        vocab_size: args.parse("vocab")?,
        zipf_alpha: args.parse("alpha")?,
        seed: args.parse("seed")?,
        stream: 0,
    };
    let mut c = SyntheticCorpus::new(spec);
    println!("entropy floor (H(next|prev)): {:.4} nats", c.entropy_floor());
    println!("unigram bound (ln V):         {:.4} nats", c.unigram_entropy_bound());
    let mut sample = vec![0u32; 32];
    c.fill(&mut sample);
    println!("sample: {sample:?}");
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = app();
    let args = match app.parse(&argv) {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            let is_help = argv
                .first()
                .map(|a| a == "--help" || a == "help" || a == "-h")
                .unwrap_or(true)
                || argv.iter().any(|a| a == "--help" || a == "-h");
            std::process::exit(if is_help { 0 } else { 2 });
        }
    };
    let result = match args.command.as_str() {
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "sweep-lr" => cmd_sweep_lr(&args),
        "inspect" => cmd_inspect(&args),
        "corpus" => cmd_corpus(&args),
        other => Err(anyhow::anyhow!("unhandled command {other}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
