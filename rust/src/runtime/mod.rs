//! Runtime — the PJRT bridge (DESIGN.md §1 "Runtime"): HLO-text artifact
//! loading, compile-once caching, execution, and Literal ⇄ native
//! conversions. Python is never on this path; artifacts come from
//! `make artifacts`.

pub mod engine;
pub mod manifest;

pub use engine::{
    literal_from_matrix, literal_from_tokens, literal_scalar, matrix_from_literal,
    scalar_from_literal, Engine,
};
pub use manifest::{BakedHyper, ConfigInfo, Manifest};
