//! PJRT execution engine: loads HLO-text artifacts, compiles them on the CPU
//! PJRT client (compile-once cache), and executes them from the training hot
//! path. Adapted from /opt/xla-example/load_hlo.
//!
//! Python never runs here — artifacts are produced once by `make artifacts`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::manifest::Manifest;
use crate::linalg::Matrix;

/// Wraps the PJRT client + compiled-executable cache.
///
/// Not `Sync`: the xla crate's wrappers are raw FFI pointers. The coordinator
/// keeps the engine on the leader thread (gradient + update execution) and
/// fans CPU-side optimizer work out to workers — the same split
/// DistributedShampoo uses between device steps and CPU root computations.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// (artifact key → cumulative execute seconds, count) for §Perf.
    timings: RefCell<HashMap<String, (f64, u64)>>,
}

impl Engine {
    /// Load the manifest and create a CPU PJRT client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Self {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            timings: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by manifest key.
    pub fn executable(&self, key: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(key) {
            return Ok(Rc::clone(e));
        }
        let file = self
            .manifest
            .artifacts
            .get(key)
            .ok_or_else(|| anyhow!("artifact '{key}' not in manifest"))?;
        let path = self.dir.join(file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {key}: {e}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(key.to_string(), Rc::clone(&exe));
        // First-compile latency is worth surfacing once per artifact.
        eprintln!("[engine] compiled {key} in {:.2}s", t0.elapsed().as_secs_f64());
        Ok(exe)
    }

    /// Execute an artifact with Literal inputs; returns the flattened tuple
    /// of output Literals.
    pub fn run(&self, key: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(key)?;
        let t0 = Instant::now();
        let out = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {key}: {e}"))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {key}: {e}"))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple {key}: {e}"))?;
        let dt = t0.elapsed().as_secs_f64();
        let mut tm = self.timings.borrow_mut();
        let e = tm.entry(key.to_string()).or_insert((0.0, 0));
        e.0 += dt;
        e.1 += 1;
        Ok(parts)
    }

    /// Cumulative (seconds, calls) per artifact — the §Perf/Fig 7 breakdown.
    pub fn timing_report(&self) -> Vec<(String, f64, u64)> {
        let mut v: Vec<_> = self
            .timings
            .borrow()
            .iter()
            .map(|(k, &(s, n))| (k.clone(), s, n))
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }

    pub fn reset_timings(&self) {
        self.timings.borrow_mut().clear();
    }
}

// ---- Literal ⇄ native conversions ----------------------------------------

/// f32 matrix → 2-D literal.
pub fn literal_from_matrix(m: &Matrix) -> Result<xla::Literal> {
    xla::Literal::vec1(&m.data)
        .reshape(&[m.rows as i64, m.cols as i64])
        .map_err(|e| anyhow!("literal reshape: {e}"))
}

/// 2-D (or scalar/1-D) literal → f32 matrix with the given shape.
pub fn matrix_from_literal(l: &xla::Literal, rows: usize, cols: usize) -> Result<Matrix> {
    let data = l.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e}"))?;
    anyhow::ensure!(data.len() == rows * cols, "literal size {} ≠ {rows}×{cols}", data.len());
    Ok(Matrix::from_vec(rows, cols, data))
}

/// Scalar f32 literal.
pub fn literal_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Token batch (u32 ids) → (batch, seq) i32 literal.
pub fn literal_from_tokens(tokens: &[u32], batch: usize, seq: usize) -> Result<xla::Literal> {
    anyhow::ensure!(tokens.len() == batch * seq);
    let as_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
    xla::Literal::vec1(&as_i32)
        .reshape(&[batch as i64, seq as i64])
        .map_err(|e| anyhow!("token literal: {e}"))
}

/// Scalar f32 out of a literal.
pub fn scalar_from_literal(l: &xla::Literal) -> Result<f32> {
    let v = l.to_vec::<f32>().map_err(|e| anyhow!("scalar literal: {e}"))?;
    v.first().copied().context("empty literal")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_matrix_roundtrip() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let l = literal_from_matrix(&m).unwrap();
        let back = matrix_from_literal(&l, 2, 3).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn token_literal_shape() {
        let l = literal_from_tokens(&[1, 2, 3, 4, 5, 6], 2, 3).unwrap();
        assert_eq!(l.element_count(), 6);
        assert!(literal_from_tokens(&[1, 2], 2, 3).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let l = literal_scalar(3.5);
        assert_eq!(scalar_from_literal(&l).unwrap(), 3.5);
    }
}
