//! Artifact manifest — the cross-language ABI written by
//! `python/compile/aot.py` and consumed by the Rust runtime/coordinator.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;

/// One model configuration as compiled into artifacts.
#[derive(Clone, Debug)]
pub struct ConfigInfo {
    pub name: String,
    pub vocab: usize,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub seq: usize,
    pub batch: usize,
    pub zloss: f64,
    /// Ordered (name, rows, cols) — the parameter ABI.
    pub params: Vec<(String, usize, usize)>,
    pub num_params: usize,
    pub non_embedding_params: usize,
}

impl ConfigInfo {
    pub fn shapes(&self) -> Vec<(usize, usize)> {
        self.params.iter().map(|&(_, r, c)| (r, c)).collect()
    }
}

/// Baked optimizer hyperparameters (must agree with `optim::Hyper`).
#[derive(Clone, Copy, Debug)]
pub struct BakedHyper {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub shampoo_beta: f32,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub hyper: BakedHyper,
    pub max_precond_dim: usize,
    pub configs: BTreeMap<String, ConfigInfo>,
    /// artifact key → file name.
    pub artifacts: BTreeMap<String, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("manifest.json missing in {dir:?} (run `make artifacts`): {e}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let h = j.get("hyper");
        let num = |v: &Json, k: &str| -> anyhow::Result<f64> {
            v.get(k)
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("manifest: missing number '{k}'"))
        };
        let hyper = BakedHyper {
            beta1: num(h, "beta1")? as f32,
            beta2: num(h, "beta2")? as f32,
            eps: num(h, "eps")? as f32,
            weight_decay: num(h, "weight_decay")? as f32,
            shampoo_beta: num(h, "shampoo_beta")? as f32,
        };
        let max_precond_dim = j
            .get("max_precond_dim")
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("manifest: missing max_precond_dim"))?;

        let mut configs = BTreeMap::new();
        for (name, c) in j
            .get("configs")
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("manifest: configs missing"))?
        {
            let params = c
                .get("params")
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("config {name}: params missing"))?
                .iter()
                .map(|p| {
                    let a = p.as_arr().unwrap();
                    (
                        a[0].as_str().unwrap().to_string(),
                        a[1].as_usize().unwrap(),
                        a[2].as_usize().unwrap(),
                    )
                })
                .collect();
            configs.insert(
                name.clone(),
                ConfigInfo {
                    name: name.clone(),
                    vocab: num(c, "vocab")? as usize,
                    dim: num(c, "dim")? as usize,
                    depth: num(c, "depth")? as usize,
                    heads: num(c, "heads")? as usize,
                    seq: num(c, "seq")? as usize,
                    batch: num(c, "batch")? as usize,
                    zloss: num(c, "zloss")?,
                    params,
                    num_params: num(c, "num_params")? as usize,
                    non_embedding_params: num(c, "non_embedding_params")? as usize,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (k, v) in j
            .get("artifacts")
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("manifest: artifacts missing"))?
        {
            let file = v
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("artifact {k}: file missing"))?;
            artifacts.insert(k.clone(), file.to_string());
        }

        Ok(Self { hyper, max_precond_dim, configs, artifacts })
    }

    pub fn config(&self, name: &str) -> anyhow::Result<&ConfigInfo> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!(
                "config '{name}' not in manifest (have: {:?}); re-run `make artifacts` with --configs",
                self.configs.keys().collect::<Vec<_>>()
            ))
    }

    pub fn has_artifact(&self, key: &str) -> bool {
        self.artifacts.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "hyper": {"beta1": 0.95, "beta2": 0.95, "eps": 1e-8,
                "weight_decay": 1e-4, "shampoo_beta": 0.95},
      "max_precond_dim": 4096,
      "configs": {
        "nano": {"vocab": 256, "dim": 64, "depth": 2, "heads": 2,
                  "seq": 64, "batch": 8, "zloss": 1e-4,
                  "params": [["embed", 256, 64], ["ln_f", 1, 64]],
                  "num_params": 16448, "non_embedding_params": 64}
      },
      "artifacts": {"lm_grads_nano": {"file": "lm_grads_nano.hlo.txt",
                                       "num_inputs": 4}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.hyper.beta1, 0.95);
        assert_eq!(m.max_precond_dim, 4096);
        let c = m.config("nano").unwrap();
        assert_eq!(c.dim, 64);
        assert_eq!(c.params.len(), 2);
        assert_eq!(c.params[0], ("embed".to_string(), 256, 64));
        assert!(m.has_artifact("lm_grads_nano"));
        assert!(!m.has_artifact("nope"));
    }

    #[test]
    fn missing_config_is_helpful_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let err = m.config("big100m").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
