//! Multi-process distributed executor with distributed eigenbasis ownership.
//!
//! N workers (separate processes over localhost TCP, or threads over an
//! in-process channel mesh) each run the FULL training loop SPMD-style: the
//! same seed drives the same [`crate::data::BatchStream`] on every rank, the
//! optimizer state is fully replicated, and two collectives keep the ranks
//! bitwise-identical to a serial run:
//!
//! - **Gradient fold-reduce** — the global batch's microbatches are split
//!   into contiguous per-rank slices; partial sums travel rank 0 → N−1 in an
//!   order-preserving chain (each rank adds its microbatch gradients ONE AT A
//!   TIME, layer-chunked) and the last rank broadcasts the result. A textbook
//!   ring all-reduce would re-associate the f32 summation differently on
//!   every rank; the chain reproduces the serial fold-left bracketing
//!   exactly, which is what makes `--backend distributed` bitwise-identical
//!   to `--backend serial`.
//! - **Eigenbasis broadcast** — each rank OWNS the periodic eigendecomposition
//!   refreshes for a deterministic subset of layers (the same cost-balanced
//!   assignment the sharded backend uses). The owner runs the refresh locally
//!   and publishes it through the existing tear-free
//!   [`crate::precond::BasisHandle`] double-buffer; the executor serializes
//!   that publication as a versioned frame, broadcasts it, and every rank
//!   adopts it at the same step (an adopt-version cap keeps any rank from
//!   running ahead). Non-owners never run the eigendecomposition at all —
//!   that is the point: refresh cost scales down ~1/N.
//!
//! Rendezvous is rank-0-centric: workers dial the coordinator, exchange a
//! config fingerprint, and receive the address table for the full peer mesh.
//! Every failure is a typed [`DistError`] carrying the local rank, the peer
//! involved, and the protocol phase — a dead or hung peer trips the
//! configurable `--dist-timeout` instead of wedging the run.

pub mod comm;
pub mod executor;
pub mod frame;
pub mod launch;
pub mod transport;

pub use comm::{microbatch_slice, DistComm};
pub use executor::DistExecutor;
pub use launch::{spawn_workers, ChildGuard};
pub use transport::{MemCluster, MemEndpoint, Transport};

use std::fmt;

/// Which protocol phase a [`DistError`] happened in — part of the typed
/// surface so operators (and the kill-a-rank integration test) can tell a
/// rendezvous misconfiguration from a mid-run peer death.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistPhase {
    /// Worker registration / address-table exchange / mesh dial-up.
    Rendezvous,
    /// The per-step gradient fold-reduce chain.
    AllReduce,
    /// Broadcasting or receiving a published eigenbasis.
    BasisBroadcast,
    /// Collecting per-rank health rows on the metrics cadence.
    HealthGather,
    /// The rank-0-centric barrier.
    Barrier,
    /// Orderly teardown.
    Shutdown,
}

impl DistPhase {
    pub fn name(&self) -> &'static str {
        match self {
            DistPhase::Rendezvous => "rendezvous",
            DistPhase::AllReduce => "allreduce",
            DistPhase::BasisBroadcast => "basis-broadcast",
            DistPhase::HealthGather => "health-gather",
            DistPhase::Barrier => "barrier",
            DistPhase::Shutdown => "shutdown",
        }
    }
}

/// A distributed-protocol failure: which rank observed it, which peer was
/// involved (when one was), and in which phase. Converts into
/// [`anyhow::Error`] at the session boundary via the std-error blanket.
#[derive(Debug)]
pub struct DistError {
    pub rank: usize,
    pub peer: Option<usize>,
    pub phase: DistPhase,
    pub detail: String,
}

impl DistError {
    pub fn new(rank: usize, phase: DistPhase, detail: impl Into<String>) -> Self {
        Self { rank, peer: None, phase, detail: detail.into() }
    }

    pub fn with_peer(rank: usize, peer: usize, phase: DistPhase, detail: impl Into<String>) -> Self {
        Self { rank, peer: Some(peer), phase, detail: detail.into() }
    }
}

impl fmt::Display for DistError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "distributed error on rank {} [{}", self.rank, self.phase.name())?;
        if let Some(p) = self.peer {
            write!(f, ", peer {p}")?;
        }
        write!(f, "]: {}", self.detail)
    }
}

impl std::error::Error for DistError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_error_display_carries_rank_peer_phase() {
        let e = DistError::with_peer(2, 0, DistPhase::AllReduce, "peer closed the connection");
        let s = e.to_string();
        assert!(s.contains("rank 2"), "{s}");
        assert!(s.contains("allreduce"), "{s}");
        assert!(s.contains("peer 0"), "{s}");
        assert!(s.contains("closed"), "{s}");
        let e = DistError::new(0, DistPhase::Rendezvous, "fingerprint mismatch");
        assert!(!e.to_string().contains("peer"), "{e}");
    }
}
