//! [`DistExecutor`] — the distributed optimizer executor behind
//! `Backend::Distributed`.
//!
//! Every rank holds the FULL replicated optimizer state (which is what makes
//! rank 0's checkpoint format-identical to a serial checkpoint), but the
//! periodic eigenbasis refreshes are partitioned: layer ownership comes from
//! the same cost-balanced assignment the sharded backend uses
//! ([`crate::coordinator::sharded::assign_shards_tensors`] over
//! `nranks` "shards"), so every rank runs ~1/N of the eigendecomposition
//! work and broadcasts the results.
//!
//! Two exchange points keep adoption step-synchronous on every rank:
//!
//! - **Mid-step** (inline Shampoo only): an inverse-root refresh feeds the
//!   SAME step's update, so when `dist_mid_step_sync` fires for a layer the
//!   owner updates that layer first and broadcasts the fresh roots; everyone
//!   else receives + adopts before touching the layer. The predicate is a
//!   pure function of replicated state, so all ranks agree on when this
//!   happens with zero extra communication.
//! - **Post-step**: each rank broadcasts exactly ONE (possibly empty) batch
//!   of its pending publications every step, in rank order, and raises the
//!   adopt caps only after the broadcast — no rank's active basis can run
//!   ahead of its peers, even under undrained async refresh. With
//!   `drain_refresh` the service is drained first, making the exchange (and
//!   therefore the whole trajectory) bitwise-deterministic.
//!
//! Init-path decompositions (SOAP's first-gradient eigh, Shampoo's first
//! inline root) intentionally run on EVERY rank: they bypass the publication
//! machinery and are cheap one-offs, and replicating them keeps the
//! first-step state identical without a broadcast.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::comm::DistComm;
use super::frame::BasisEntry;
use super::{DistError, DistPhase};
use crate::linalg::{Matrix, TensorShape};
use crate::optim::{Hyper, LayerOptimizer, OptKind, RefreshMode};
use crate::precond::{DistBasisPort, RefreshService};
use crate::runtime::Engine;
use crate::session::backend::ExecutorBackend;
use crate::session::{LayerHealth, RankHealth};

/// Distributed executor: replicated per-layer optimizer slots plus the
/// refresh-ownership map and the basis ports the exchange protocol drives.
pub struct DistExecutor {
    comm: Arc<DistComm>,
    slots: Vec<Box<dyn LayerOptimizer>>,
    refresh_service: Option<Arc<RefreshService>>,
    /// `owner[layer]` = rank that runs this layer's periodic refreshes.
    owner: Vec<usize>,
    /// `ports[layer]` = broadcast mailboxes, in `attach_dist` order (the
    /// wire address is `(layer, port_idx)`).
    ports: Vec<Vec<DistBasisPort>>,
    /// Drain the refresh service before the post-step exchange (the
    /// deterministic-async contract).
    drain: bool,
    /// Publications this rank has broadcast (ownership telemetry).
    owned_refreshes: u64,
}

impl DistExecutor {
    pub fn new_tensors(
        kind: OptKind,
        hyper: &Hyper,
        shapes: &[TensorShape],
        comm: Arc<DistComm>,
        drain: bool,
    ) -> Self {
        let mut slots: Vec<Box<dyn LayerOptimizer>> = shapes
            .iter()
            .enumerate()
            .map(|(idx, shape)| kind.build_staggered_tensor(idx, shape, hyper))
            .collect();
        // Same async-service policy as the serial/sharded executors.
        let refresh_service = (hyper.refresh_mode == RefreshMode::Async)
            .then(|| Arc::new(RefreshService::new(hyper.refresh_workers)))
            .filter(|svc| {
                let mut any = false;
                for slot in slots.iter_mut() {
                    any |= slot.attach_async(svc);
                }
                any
            });
        // Refresh ownership: the sharded backend's deterministic
        // cost-balanced assignment, with "shards" = ranks.
        let owner = crate::coordinator::sharded::assign_shards_tensors(shapes, comm.nranks());
        let rank = comm.rank();
        let ports = slots
            .iter_mut()
            .zip(&owner)
            .map(|(slot, &o)| slot.attach_dist(o == rank))
            .collect();
        Self { comm, slots, refresh_service, owner, ports, drain, owned_refreshes: 0 }
    }

    /// The communicator (rank/traffic introspection; tests).
    pub fn comm(&self) -> &Arc<DistComm> {
        &self.comm
    }

    /// The refresh-ownership map, layer-ordered (tests, docs tooling).
    pub fn owners(&self) -> &[usize] {
        &self.owner
    }

    /// Publications not yet broadcast for `layer`: handle version above the
    /// adopt cap means the executor still owes peers this basis.
    fn collect_pending(&self, layer: usize, out: &mut Vec<BasisEntry>) {
        for (port_idx, port) in self.ports[layer].iter().enumerate() {
            if port.handle.version() > port.adopt_cap.load(Ordering::Acquire) {
                if let Some(p) = port.handle.latest() {
                    out.push(BasisEntry {
                        layer: layer as u32,
                        port: port_idx as u32,
                        snapshot_step: p.snapshot_step,
                        version: p.version,
                        payload: p.payload.clone(),
                    });
                }
            }
        }
    }

    /// Owner side: ship `entries` to every peer, then raise the local caps
    /// to EXACTLY the broadcast versions (not `handle.version()` — the async
    /// service may publish again between collect and cap, and that newer
    /// publication must wait for the next exchange).
    fn bcast_and_cap(&mut self, entries: Vec<BasisEntry>) -> Result<(), DistError> {
        self.comm.bcast_basis(&entries)?;
        for e in &entries {
            self.ports[e.layer as usize][e.port as usize].raise_cap(e.version);
        }
        self.owned_refreshes += entries.len() as u64;
        Ok(())
    }

    /// Receiver side: publish each entry into the addressed local mailbox
    /// and raise its cap so the next `adopt_published` takes it.
    fn apply_entries(&self, entries: Vec<BasisEntry>, from: usize) -> Result<(), DistError> {
        for e in entries {
            let port = self
                .ports
                .get(e.layer as usize)
                .and_then(|ps| ps.get(e.port as usize))
                .ok_or_else(|| {
                    DistError::with_peer(
                        self.comm.rank(),
                        from,
                        DistPhase::BasisBroadcast,
                        format!("basis entry addresses unknown port ({}, {})", e.layer, e.port),
                    )
                })?;
            // Versions are per-handle local counters; the cap is raised to
            // OUR publish's version, which need not equal the owner's.
            let v = port.handle.publish(e.payload, e.snapshot_step);
            port.raise_cap(v);
        }
        Ok(())
    }
}

impl ExecutorBackend for DistExecutor {
    fn name(&self) -> &'static str {
        "distributed"
    }

    fn step(
        &mut self,
        _engine: Option<&Engine>,
        params: &mut [Matrix],
        grads: &[Matrix],
        t: u64,
        lr: f32,
    ) -> Result<()> {
        anyhow::ensure!(params.len() == self.slots.len(), "layer count mismatch");
        let rank = self.comm.rank();
        for idx in 0..self.slots.len() {
            // Pure function of replicated state — every rank computes the
            // same value, so the frame pattern below needs no negotiation.
            let mid_sync = self.slots[idx].dist_mid_step_sync(t);
            if mid_sync && self.owner[idx] != rank {
                let owner = self.owner[idx];
                let entries = self.comm.recv_basis(owner)?;
                self.apply_entries(entries, owner)?;
            }
            self.slots[idx].update(&mut params[idx], &grads[idx], t, lr);
            if mid_sync && self.owner[idx] == rank {
                let mut pending = Vec::new();
                self.collect_pending(idx, &mut pending);
                self.bcast_and_cap(pending)?;
            }
        }
        // Post-step exchange: exactly one basis-batch frame from every rank,
        // in rank order. Deterministic frame count, deadlock-free, and it
        // runs HERE rather than at checkpoint/idle time so `prepare_export`
        // never needs a collective (rank 0 checkpoints alone).
        if self.drain {
            if let Some(svc) = &self.refresh_service {
                svc.wait_idle();
            }
        }
        for r in 0..self.comm.nranks() {
            if r == rank {
                let mut pending = Vec::new();
                for idx in 0..self.slots.len() {
                    if self.owner[idx] == rank {
                        self.collect_pending(idx, &mut pending);
                    }
                }
                self.bcast_and_cap(pending)?;
            } else {
                let entries = self.comm.recv_basis(r)?;
                self.apply_entries(entries, r)?;
            }
        }
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.state_bytes()).sum()
    }

    fn scratch_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.scratch_bytes()).sum()
    }

    fn refresh_seconds(&self) -> f64 {
        self.slots.iter().map(|s| s.refresh_seconds()).sum()
    }

    fn async_refresh_seconds(&self) -> f64 {
        self.refresh_service.as_ref().map(|s| s.refresh_seconds()).unwrap_or(0.0)
    }

    fn mean_basis_staleness(&self, t: u64) -> f64 {
        let (mut sum, mut n) = (0.0f64, 0u32);
        for slot in &self.slots {
            if let Some(snap) = slot.basis_snapshot_step() {
                sum += t.saturating_sub(snap) as f64;
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    fn collect_layer_health(&self, t: u64) -> Vec<LayerHealth> {
        self.slots
            .iter()
            .enumerate()
            .map(|(layer, slot)| LayerHealth {
                layer,
                grad_norm: None,
                update_norm: slot.update_norm(),
                staleness: slot.basis_snapshot_step().map(|snap| t.saturating_sub(snap)),
                whitening_offdiag: slot.whitening_offdiag(),
            })
            .collect()
    }

    fn dist_rank_health(&self) -> Option<RankHealth> {
        let rank = self.comm.rank();
        let (frames_sent, frames_recv, bytes_sent, bytes_recv, allreduce_s) = self.comm.traffic();
        Some(RankHealth {
            rank,
            owned_layers: self.owner.iter().filter(|&&o| o == rank).count(),
            owned_refreshes: self.owned_refreshes,
            frames_sent,
            frames_recv,
            bytes_sent,
            bytes_recv,
            allreduce_s,
        })
    }

    fn refresh_queue_depth(&self) -> usize {
        self.refresh_service.as_ref().map(|s| s.pending()).unwrap_or(0)
    }

    fn refresh_pool_stats(&self) -> Option<(u64, f64)> {
        self.refresh_service.as_ref().map(|s| s.pool_stats())
    }

    fn wait_refresh_idle(&self) {
        if let Some(svc) = &self.refresh_service {
            svc.wait_idle();
        }
    }

    fn prepare_export(&mut self) {
        // No collectives here: rank 0 checkpoints alone. Caps are already
        // current in inline and drained-async modes (the post-step exchange
        // runs every step); an undrained-async publication that has not been
        // broadcast yet is simply not in the checkpoint — the same "refresh
        // in flight is lost" semantics an undrained serial checkpoint has.
        self.wait_refresh_idle();
        for slot in self.slots.iter_mut() {
            slot.finish_pending();
        }
    }

    fn export_state(&self) -> Result<Vec<(usize, Vec<Matrix>)>> {
        Ok(self.slots.iter().enumerate().map(|(i, s)| (i, s.export_state())).collect())
    }

    fn import_state(&mut self, mut state: Vec<(usize, Vec<Matrix>)>) -> Result<()> {
        state.sort_by_key(|&(i, _)| i);
        for (idx, slot) in self.slots.iter_mut().enumerate() {
            let pos = state
                .binary_search_by_key(&idx, |&(i, _)| i)
                .map_err(|_| anyhow!("missing state for layer {idx}"))?;
            slot.import_state(std::mem::take(&mut state[pos].1))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::MemCluster;
    use crate::session::backend::SerialExecutor;
    use crate::util::rng::Rng;
    use std::time::Duration;

    fn shapes() -> Vec<TensorShape> {
        [(12, 12), (1, 24), (8, 16), (16, 8), (24, 4)]
            .iter()
            .map(|&(m, n)| TensorShape::matrix(m, n))
            .collect()
    }

    /// Shared grads/params script: a pure function of the seed, so serial
    /// and every distributed rank regenerate identical inputs.
    fn script(seed: u64, steps: u64) -> (Vec<Matrix>, Vec<Vec<Matrix>>) {
        let shapes = shapes();
        let mut rng = Rng::new(seed);
        let init: Vec<Matrix> = shapes
            .iter()
            .map(|s| {
                let (m, n) = s.carrier();
                Matrix::randn(&mut rng, m, n, 1.0)
            })
            .collect();
        let grads: Vec<Vec<Matrix>> = (0..steps)
            .map(|_| {
                shapes
                    .iter()
                    .map(|s| {
                        let (m, n) = s.carrier();
                        Matrix::randn(&mut rng, m, n, 1.0)
                    })
                    .collect()
            })
            .collect();
        (init, grads)
    }

    fn run_distributed(
        kind: OptKind,
        hyper: &Hyper,
        nranks: usize,
        steps: u64,
    ) -> Vec<(Vec<Matrix>, RankHealth)> {
        let handles: Vec<_> = MemCluster::new(nranks)
            .into_iter()
            .map(|ep| {
                let hyper = hyper.clone();
                std::thread::spawn(move || {
                    let comm =
                        Arc::new(DistComm::connect_mem(ep, Duration::from_secs(20)).unwrap());
                    let mut exec =
                        DistExecutor::new_tensors(kind, &hyper, &shapes(), comm, true);
                    let (mut params, grads) = script(77, steps);
                    for (i, g) in grads.iter().enumerate() {
                        exec.step(None, &mut params, g, i as u64 + 1, 0.01).unwrap();
                    }
                    let health = exec.dist_rank_health().unwrap();
                    (params, health)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn distributed_matches_serial_bitwise_with_owned_refreshes() {
        // SOAP exercises the post-step (rotation) exchange; Shampoo the
        // mid-step inverse-root sync. Both must be bitwise vs serial.
        for kind in [OptKind::Soap, OptKind::Shampoo] {
            let hyper = Hyper { precond_freq: 3, ..Hyper::default() };
            let steps = 10;
            let mut serial = SerialExecutor::new_tensors(kind, &hyper, &shapes());
            let (mut sp, grads) = script(77, steps);
            for (i, g) in grads.iter().enumerate() {
                serial.step(None, &mut sp, g, i as u64 + 1, 0.01).unwrap();
            }
            for nranks in [2usize, 3] {
                let results = run_distributed(kind, &hyper, nranks, steps);
                let mut total_owned = 0;
                for (rank, (params, health)) in results.iter().enumerate() {
                    for (l, (a, b)) in params.iter().zip(&sp).enumerate() {
                        for (x, y) in a.data.iter().zip(&b.data) {
                            assert_eq!(
                                x.to_bits(),
                                y.to_bits(),
                                "{kind:?} rank {rank}/{nranks} layer {l} diverged from serial"
                            );
                        }
                    }
                    assert_eq!(health.rank, rank);
                    total_owned += health.owned_refreshes;
                    assert!(
                        health.owned_layers > 0,
                        "{kind:?}: rank {rank}/{nranks} owns no layers — assignment degenerate"
                    );
                }
                assert!(total_owned > 0, "{kind:?}: no refresh was ever broadcast");
                assert!(
                    results.iter().skip(1).any(|(_, h)| h.owned_refreshes > 0),
                    "{kind:?}: every broadcast refresh ran on rank 0 — ownership not distributed"
                );
            }
        }
    }

    #[test]
    fn distributed_async_drained_matches_serial_async_drained() {
        let hyper = Hyper { precond_freq: 3, ..Hyper::default() }.async_refresh();
        let steps = 9;
        let mut serial = SerialExecutor::new_tensors(OptKind::Soap, &hyper, &shapes());
        let (mut sp, grads) = script(41, steps);
        for (i, g) in grads.iter().enumerate() {
            serial.step(None, &mut sp, g, i as u64 + 1, 0.01).unwrap();
            serial.wait_refresh_idle();
        }
        let (mut dp, grads) = script(41, steps);
        let mut eps = MemCluster::new(2);
        let ep1 = eps.pop().unwrap();
        let worker = {
            let hyper = hyper.clone();
            std::thread::spawn(move || {
                let comm = Arc::new(DistComm::connect_mem(ep1, Duration::from_secs(20)).unwrap());
                let mut exec =
                    DistExecutor::new_tensors(OptKind::Soap, &hyper, &shapes(), comm, true);
                let (mut params, grads) = script(41, steps);
                for (i, g) in grads.iter().enumerate() {
                    exec.step(None, &mut params, g, i as u64 + 1, 0.01).unwrap();
                }
                params
            })
        };
        let ep0 = eps.pop().unwrap();
        let comm = Arc::new(DistComm::connect_mem(ep0, Duration::from_secs(20)).unwrap());
        let mut rank0 = DistExecutor::new_tensors(OptKind::Soap, &hyper, &shapes(), comm, true);
        for (i, g) in grads.iter().enumerate() {
            rank0.step(None, &mut dp, g, i as u64 + 1, 0.01).unwrap();
        }
        let worker_params = worker.join().expect("rank 1 thread panicked");
        for (a, b) in worker_params.iter().zip(&dp) {
            assert_eq!(a.data, b.data, "rank 1 state diverged from rank 0");
        }
        // Drained-async adoption timing is a pure function of the step
        // count, so the distributed drained run must equal serial drained.
        for (l, (a, b)) in dp.iter().zip(&sp).enumerate() {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "async drained layer {l} diverged");
            }
        }
    }
}
