//! Worker-process launch for the self-spawning distributed CLI mode.
//!
//! `soap-lab train --backend distributed --ranks N` makes the invoking
//! process rank 0: it binds the coordinator listener, re-executes its own
//! binary N−1 times with `--rank r --coordinator-addr <addr>` appended, and
//! trains alongside the children. [`ChildGuard`] owns the children for the
//! duration: if rank 0 fails (or panics, or is interrupted past the guard's
//! drop), every child is killed — no orphan workers grinding on after the
//! coordinator is gone. Manual launch (operator starts each rank by hand
//! with `--rank`/`--coordinator-addr`) bypasses this module entirely.

use std::process::{Child, Command, Stdio};

/// Spawn worker ranks `1..nranks` as copies of the current executable.
///
/// `argv` is the base argument vector to replay (typically the parent's own
/// CLI args minus the program name); each child gets
/// `--rank <r> --coordinator-addr <coordinator>` appended, which the CLI
/// parser treats as "join an existing rendezvous" rather than self-spawn.
/// Children inherit stdout/stderr so worker-side failures are visible in the
/// parent's terminal.
pub fn spawn_workers(
    nranks: usize,
    coordinator: &str,
    argv: &[String],
) -> std::io::Result<ChildGuard> {
    let exe = std::env::current_exe()?;
    let mut children = Vec::with_capacity(nranks.saturating_sub(1));
    for rank in 1..nranks {
        let spawned = Command::new(&exe)
            .args(argv)
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--coordinator-addr")
            .arg(coordinator)
            .stdin(Stdio::null())
            .spawn();
        match spawned {
            Ok(child) => children.push((rank, child)),
            Err(e) => {
                // Partial spawn: reap what already started before bailing.
                drop(ChildGuard { children });
                return Err(e);
            }
        }
    }
    Ok(ChildGuard { children })
}

/// Owns spawned worker processes; `Drop` kills any still running. Call
/// [`ChildGuard::wait_all`] on the success path to reap them cleanly and
/// surface a nonzero worker exit as an error.
pub struct ChildGuard {
    children: Vec<(usize, Child)>,
}

impl ChildGuard {
    /// Wait for every worker to exit; error if any exited nonzero. Consumes
    /// the guard, so the kill-on-drop safety net is disarmed only once every
    /// child has actually been reaped. A failed `wait` on one child must not
    /// leave later children unreaped, so errors are collected rather than
    /// returned early.
    pub fn wait_all(mut self) -> std::io::Result<()> {
        let mut failed = Vec::new();
        for (rank, child) in self.children.iter_mut() {
            match child.wait() {
                Ok(status) if status.success() => {}
                Ok(status) => failed.push(format!("rank {rank} exited with {status}")),
                Err(e) => failed.push(format!("rank {rank} wait failed: {e}")),
            }
        }
        self.children.clear();
        if failed.is_empty() {
            Ok(())
        } else {
            Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                format!("worker failure: {}", failed.join("; ")),
            ))
        }
    }
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        for (_, child) in self.children.iter_mut() {
            // Already-exited children make kill() a no-op error — ignore it;
            // wait() after kill prevents zombies either way.
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}
