//! Wire codec for the distributed protocol: length-prefixed frames with a
//! one-byte type tag and little-endian fixed-width payloads.
//!
//! Matrices serialize their `f32` elements via `to_le_bytes`, so a decoded
//! matrix is BITWISE the encoder's matrix — the whole distributed-vs-serial
//! golden guarantee rides on this round-trip being exact (no text formatting,
//! no f64 widening).
//!
//! On TCP the frame is `[u32 len][u8 type][u32 seq][payload]` where `len`
//! counts the type byte, the sequence number, and the payload; on the
//! in-process channel transport a frame is the `[type][seq][payload]` byte
//! vector (the channel preserves message boundaries). `seq` is a per-link
//! monotone counter that lets the receiver discard an injected/duplicated
//! retransmit and detect a gap as a typed protocol error instead of a
//! desync; heartbeat frames carry the sentinel
//! [`crate::dist::comm::HEARTBEAT_SEQ`] and are sequence-exempt.

use crate::linalg::Matrix;
use crate::precond::BasisPayload;

// Frame type tags. Stable wire values — add, never renumber.
/// Worker → coordinator registration: rank, mesh listen port, fingerprint.
pub const FRAME_HELLO: u8 = 1;
/// Coordinator → workers: the full rank → mesh-port address table.
pub const FRAME_TOPOLOGY: u8 = 2;
/// One layer's gradient partial sum in the fold-reduce chain.
pub const FRAME_GRAD_CHUNK: u8 = 3;
/// A batch of published eigenbasis payloads from their owning rank.
pub const FRAME_BASIS_BATCH: u8 = 4;
/// One rank's health row (health gather).
pub const FRAME_HEALTH: u8 = 5;
/// Barrier token.
pub const FRAME_BARRIER: u8 = 6;
/// Orderly shutdown notice.
pub const FRAME_SHUTDOWN: u8 = 7;
/// Mesh link identification (dialing rank announces itself).
pub const FRAME_MESH_HELLO: u8 = 8;
/// Scalar trailer of the fold-reduce chain (f64 loss partial).
pub const FRAME_SCALARS: u8 = 9;
/// Liveness probe (empty payload, sequence-exempt — see `HEARTBEAT_SEQ`).
pub const FRAME_HEARTBEAT: u8 = 10;

pub fn frame_name(ty: u8) -> &'static str {
    match ty {
        FRAME_HELLO => "hello",
        FRAME_TOPOLOGY => "topology",
        FRAME_GRAD_CHUNK => "grad-chunk",
        FRAME_BASIS_BATCH => "basis-batch",
        FRAME_HEALTH => "health",
        FRAME_BARRIER => "barrier",
        FRAME_SHUTDOWN => "shutdown",
        FRAME_MESH_HELLO => "mesh-hello",
        FRAME_SCALARS => "scalars",
        FRAME_HEARTBEAT => "heartbeat",
        _ => "unknown",
    }
}

// ---- primitive writers ---------------------------------------------------

pub fn put_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

pub fn put_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

pub fn put_f64(buf: &mut Vec<u8>, x: f64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

pub fn put_matrix(buf: &mut Vec<u8>, m: &Matrix) {
    put_u32(buf, m.rows as u32);
    put_u32(buf, m.cols as u32);
    buf.reserve(m.data.len() * 4);
    for &x in &m.data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

pub fn put_opt_matrix(buf: &mut Vec<u8>, m: &Option<Matrix>) {
    match m {
        Some(m) => {
            buf.push(1);
            put_matrix(buf, m);
        }
        None => buf.push(0),
    }
}

// ---- cursor reader -------------------------------------------------------

/// Bounds-checked little-endian reader over a received payload. Decode
/// errors are plain strings; the comm layer wraps them into [`DistError`]
/// with the rank/peer/phase context it alone knows.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated frame: wanted {n} bytes at offset {}, {} left",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn matrix(&mut self) -> Result<Matrix, String> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let n = rows
            .checked_mul(cols)
            .ok_or_else(|| format!("matrix dims overflow: {rows}×{cols}"))?;
        let bytes = self.take(n * 4)?;
        let mut data = Vec::with_capacity(n);
        for chunk in bytes.chunks_exact(4) {
            data.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(Matrix::from_vec(rows, cols, data))
    }

    pub fn opt_matrix(&mut self) -> Result<Option<Matrix>, String> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.matrix()?)),
            other => Err(format!("bad option tag {other}")),
        }
    }
}

// ---- basis batch ---------------------------------------------------------

/// One published eigenbasis in flight: the wire form of a
/// [`crate::precond::BasisHandle`] publication, addressed by
/// `(layer, port)` — ports are the deterministic per-layer list
/// `LayerOptimizer::attach_dist` returned on every rank (a 2-D eigenbasis
/// has one port; a rank-k tensor basis one per active mode, in mode order).
#[derive(Clone, Debug)]
pub struct BasisEntry {
    pub layer: u32,
    pub port: u32,
    pub snapshot_step: u64,
    /// The owner's handle version for this publication. Advisory on the
    /// receiving side: each rank's handle numbers its own publications, and
    /// the adopt cap is raised to the LOCAL version — cross-rank agreement
    /// is on payload + adoption step, not on version arithmetic.
    pub version: u64,
    pub payload: BasisPayload,
}

pub fn encode_basis_batch(entries: &[BasisEntry]) -> Vec<u8> {
    let mut buf = Vec::new();
    put_u32(&mut buf, entries.len() as u32);
    for e in entries {
        put_u32(&mut buf, e.layer);
        put_u32(&mut buf, e.port);
        put_u64(&mut buf, e.snapshot_step);
        put_u64(&mut buf, e.version);
        put_opt_matrix(&mut buf, &e.payload.left);
        put_opt_matrix(&mut buf, &e.payload.right);
        put_opt_matrix(&mut buf, &e.payload.left_aux);
        put_opt_matrix(&mut buf, &e.payload.right_aux);
    }
    buf
}

pub fn decode_basis_batch(buf: &[u8]) -> Result<Vec<BasisEntry>, String> {
    let mut c = Cursor::new(buf);
    let n = c.u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(BasisEntry {
            layer: c.u32()?,
            port: c.u32()?,
            snapshot_step: c.u64()?,
            version: c.u64()?,
            payload: BasisPayload {
                left: c.opt_matrix()?,
                right: c.opt_matrix()?,
                left_aux: c.opt_matrix()?,
                right_aux: c.opt_matrix()?,
            },
        });
    }
    if !c.done() {
        return Err(format!("basis batch has {} trailing bytes", c.remaining()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_roundtrip_is_bitwise() {
        // Include values that would NOT survive a text round-trip.
        let m = Matrix::from_vec(
            2,
            3,
            vec![0.1f32, -0.0, f32::MIN_POSITIVE, 1.0e-7, 3.4e38, 1.0 / 3.0],
        );
        let mut buf = Vec::new();
        put_matrix(&mut buf, &m);
        let back = Cursor::new(&buf).matrix().unwrap();
        assert_eq!(back.rows, 2);
        assert_eq!(back.cols, 3);
        for (a, b) in m.data.iter().zip(&back.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "codec changed a bit pattern");
        }
    }

    #[test]
    fn basis_batch_roundtrip() {
        let entries = vec![
            BasisEntry {
                layer: 3,
                port: 1,
                snapshot_step: 40,
                version: 7,
                payload: BasisPayload {
                    left: Some(Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0])),
                    right: None,
                    left_aux: Some(Matrix::from_vec(1, 2, vec![0.5, -0.5])),
                    right_aux: None,
                },
            },
            BasisEntry {
                layer: 0,
                port: 0,
                snapshot_step: 8,
                version: 1,
                payload: BasisPayload { left: None, right: None, left_aux: None, right_aux: None },
            },
        ];
        let buf = encode_basis_batch(&entries);
        let back = decode_basis_batch(&buf).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].layer, 3);
        assert_eq!(back[0].port, 1);
        assert_eq!(back[0].version, 7);
        assert_eq!(back[0].payload.left.as_ref().unwrap().data, vec![1.0, 2.0, 3.0, 4.0]);
        assert!(back[0].payload.right.is_none());
        assert_eq!(back[1].snapshot_step, 8);
        assert!(back[1].payload.left.is_none());
    }

    #[test]
    fn truncated_frames_error_cleanly() {
        let mut buf = Vec::new();
        put_matrix(&mut buf, &Matrix::from_vec(2, 2, vec![1.0; 4]));
        assert!(Cursor::new(&buf[..buf.len() - 1]).matrix().is_err());
        assert!(decode_basis_batch(&[1, 0, 0]).is_err());
    }
}
