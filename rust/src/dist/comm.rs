//! [`DistComm`] — one rank's view of the worker mesh: rendezvous, framed
//! point-to-point links, and the three collectives the distributed executor
//! needs (gradient fold-reduce, basis broadcast, health gather) plus a
//! rank-0-centric barrier.
//!
//! ## Determinism contract
//!
//! [`DistComm::fold_all_reduce`] reproduces the serial gradient-accumulation
//! fold EXACTLY: microbatch partial sums travel rank 0 → N−1 with each rank
//! adding its per-microbatch gradients one at a time (never pre-folded), so
//! the f32 summation tree is the serial fold-left chain regardless of rank
//! count. The last rank broadcasts the finished (unscaled) sum; every rank
//! then applies the identical `1/k` scale. Losses ride the same chain in
//! f64, matching the serial accumulator's width.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::frame::{
    self, BasisEntry, Cursor, FRAME_BARRIER, FRAME_BASIS_BATCH, FRAME_GRAD_CHUNK, FRAME_HEALTH,
    FRAME_HEARTBEAT, FRAME_HELLO, FRAME_MESH_HELLO, FRAME_SCALARS, FRAME_SHUTDOWN, FRAME_TOPOLOGY,
};
use super::transport::{accept_deadline, connect_deadline, tcp_read_frame, tcp_write_frame};
use super::transport::MemEndpoint;
use super::{DistError, DistPhase};
use crate::linalg::Matrix;
use crate::session::RankHealth;

/// Sequence number carried by heartbeat frames: heartbeats are pure
/// liveness probes injected between protocol frames by the monitor thread,
/// so they are exempt from the per-link ordering contract.
pub const HEARTBEAT_SEQ: u32 = u32::MAX;

/// Sequence number on rendezvous-phase frames, which are exchanged on raw
/// streams before the per-link counters start (readers ignore it).
const RENDEZVOUS_SEQ: u32 = 0;

/// Contiguous microbatch slice owned by `rank` out of `k` total: the first
/// `k % nranks` ranks take one extra. Returns `(start, count)`.
pub fn microbatch_slice(rank: usize, nranks: usize, k: usize) -> (usize, usize) {
    let base = k / nranks;
    let extra = k % nranks;
    let count = base + usize::from(rank < extra);
    let start = rank * base + rank.min(extra);
    (start, count)
}

/// Traffic counters for one rank (instance-scoped, unlike the process-global
/// telemetry registry — the mem transport runs every rank in one process, so
/// per-rank attribution has to live here).
#[derive(Default)]
struct Counters {
    frames_sent: AtomicU64,
    frames_recv: AtomicU64,
    bytes_sent: AtomicU64,
    bytes_recv: AtomicU64,
    allreduce_nanos: AtomicU64,
}

enum Wire {
    /// `links[peer]` is the framed stream to that peer (`None` at self).
    Tcp(Vec<Option<Mutex<TcpStream>>>),
    Mem(MemEndpoint),
}

/// One rank's communicator over the full peer mesh.
pub struct DistComm {
    rank: usize,
    nranks: usize,
    timeout: Duration,
    wire: Wire,
    counters: Counters,
    /// Per-peer next outgoing sequence number (heartbeats excluded).
    send_seq: Vec<AtomicU64>,
    /// Per-peer next expected incoming sequence number.
    recv_seq: Vec<AtomicU64>,
    /// Millis since `epoch` anything was last read from each peer —
    /// heartbeat or data. Feeds the silence gauge.
    last_heard: Vec<AtomicU64>,
    epoch: Instant,
}

impl DistComm {
    fn new_with_wire(rank: usize, nranks: usize, timeout: Duration, wire: Wire) -> Self {
        Self {
            rank,
            nranks,
            timeout,
            wire,
            counters: Counters::default(),
            send_seq: (0..nranks).map(|_| AtomicU64::new(0)).collect(),
            recv_seq: (0..nranks).map(|_| AtomicU64::new(0)).collect(),
            last_heard: (0..nranks).map(|_| AtomicU64::new(0)).collect(),
            epoch: Instant::now(),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn nranks(&self) -> usize {
        self.nranks
    }

    /// Wrap a [`MemEndpoint`] (from [`super::MemCluster::new`]) — the
    /// in-process transport has no rendezvous to run.
    pub fn connect_mem(endpoint: MemEndpoint, timeout: Duration) -> Result<Self, DistError> {
        if endpoint.nranks < 2 {
            return Err(DistError::new(
                endpoint.rank,
                DistPhase::Rendezvous,
                "distributed backend needs at least 2 ranks",
            ));
        }
        let (rank, nranks) = (endpoint.rank, endpoint.nranks);
        Ok(Self::new_with_wire(rank, nranks, timeout, Wire::Mem(endpoint)))
    }

    /// Full TCP rendezvous. Rank 0 owns `listener` (binding
    /// `coordinator_addr` itself when the launcher didn't pre-bind one),
    /// collects a `Hello{rank, mesh_port, fingerprint}` from every worker,
    /// validates the fingerprints, and broadcasts the mesh address table;
    /// every pair of nonzero ranks then dials lower-rank → listener so the
    /// mesh is complete. Ends with a barrier, so a returned communicator
    /// means every rank is fully connected.
    pub fn connect_tcp(
        rank: usize,
        nranks: usize,
        coordinator_addr: &str,
        listener: Option<TcpListener>,
        timeout: Duration,
        fingerprint: u64,
    ) -> Result<Self, DistError> {
        let ph = DistPhase::Rendezvous;
        if nranks < 2 {
            return Err(DistError::new(rank, ph, "distributed backend needs at least 2 ranks"));
        }
        if rank >= nranks {
            return Err(DistError::new(rank, ph, format!("rank {rank} out of range for {nranks} ranks")));
        }
        let deadline = Instant::now() + timeout;
        let io = |peer: Option<usize>, what: &str, e: &dyn std::fmt::Display| DistError {
            rank,
            peer,
            phase: ph,
            detail: format!("{what}: {e}"),
        };
        let prep = |s: &TcpStream| -> std::io::Result<()> {
            s.set_nodelay(true)?;
            s.set_read_timeout(Some(timeout))
        };

        let mut links: Vec<Option<Mutex<TcpStream>>> = (0..nranks).map(|_| None).collect();
        if rank == 0 {
            let listener = match listener {
                Some(l) => l,
                None => TcpListener::bind(coordinator_addr)
                    .map_err(|e| io(None, &format!("binding coordinator {coordinator_addr}"), &e))?,
            };
            let mut ports = vec![0u32; nranks];
            for _ in 1..nranks {
                let mut s = accept_deadline(&listener, deadline)
                    .map_err(|e| io(None, "waiting for workers to register", &e))?;
                prep(&s).map_err(|e| io(None, "configuring worker socket", &e))?;
                let (ty, _, payload) = tcp_read_frame(&mut s)
                    .map_err(|e| io(None, "reading worker hello", &e))?;
                if ty != FRAME_HELLO {
                    return Err(io(None, "expected hello frame, got", &frame::frame_name(ty)));
                }
                let mut c = Cursor::new(&payload);
                let (r, port, fp) = (|| -> Result<_, String> {
                    Ok((c.u32()? as usize, c.u32()?, c.u64()?))
                })()
                .map_err(|e| io(None, "decoding hello", &e))?;
                if r == 0 || r >= nranks {
                    return Err(io(None, "worker announced invalid rank", &r));
                }
                if links[r].is_some() {
                    return Err(io(Some(r), "duplicate registration for rank", &r));
                }
                if fp != fingerprint {
                    return Err(DistError::with_peer(
                        rank,
                        r,
                        ph,
                        format!(
                            "config fingerprint mismatch (coordinator {fingerprint:#018x}, \
                             worker {fp:#018x}) — every rank must run the identical \
                             model/optimizer/data configuration"
                        ),
                    ));
                }
                ports[r] = port;
                links[r] = Some(Mutex::new(s));
            }
            let mut payload = Vec::with_capacity(4 + 4 * nranks);
            frame::put_u32(&mut payload, nranks as u32);
            for &p in &ports {
                frame::put_u32(&mut payload, p);
            }
            for (r, link) in links.iter().enumerate().skip(1) {
                let mut s = link.as_ref().unwrap().lock().unwrap();
                tcp_write_frame(&mut s, FRAME_TOPOLOGY, RENDEZVOUS_SEQ, &payload)
                    .map_err(|e| io(Some(r), "sending topology", &e))?;
            }
        } else {
            // Mesh listener first, so its port rides in the hello.
            let mesh_listener = TcpListener::bind("127.0.0.1:0")
                .map_err(|e| io(None, "binding mesh listener", &e))?;
            let my_port = mesh_listener
                .local_addr()
                .map_err(|e| io(None, "reading mesh listener addr", &e))?
                .port() as u32;
            let mut coord = connect_deadline(coordinator_addr, deadline)
                .map_err(|e| io(Some(0), "dialing coordinator", &e))?;
            prep(&coord).map_err(|e| io(Some(0), "configuring coordinator socket", &e))?;
            let mut hello = Vec::with_capacity(16);
            frame::put_u32(&mut hello, rank as u32);
            frame::put_u32(&mut hello, my_port);
            frame::put_u64(&mut hello, fingerprint);
            tcp_write_frame(&mut coord, FRAME_HELLO, RENDEZVOUS_SEQ, &hello)
                .map_err(|e| io(Some(0), "sending hello", &e))?;
            let (ty, _, payload) =
                tcp_read_frame(&mut coord).map_err(|e| io(Some(0), "reading topology", &e))?;
            if ty != FRAME_TOPOLOGY {
                return Err(io(Some(0), "expected topology frame, got", &frame::frame_name(ty)));
            }
            let ports = (|| -> Result<Vec<u32>, String> {
                let mut c = Cursor::new(&payload);
                let n = c.u32()? as usize;
                if n != nranks {
                    return Err(format!("coordinator reports {n} ranks, this worker expects {nranks}"));
                }
                (0..n).map(|_| c.u32()).collect()
            })()
            .map_err(|e| io(Some(0), "decoding topology", &e))?;
            links[0] = Some(Mutex::new(coord));
            // Dial every lower nonzero rank; accept from every higher one.
            for (j, port) in ports.iter().enumerate().take(rank).skip(1) {
                let mut s = connect_deadline(&format!("127.0.0.1:{port}"), deadline)
                    .map_err(|e| io(Some(j), "dialing mesh peer", &e))?;
                prep(&s).map_err(|e| io(Some(j), "configuring mesh socket", &e))?;
                let mut m = Vec::with_capacity(4);
                frame::put_u32(&mut m, rank as u32);
                tcp_write_frame(&mut s, FRAME_MESH_HELLO, RENDEZVOUS_SEQ, &m)
                    .map_err(|e| io(Some(j), "sending mesh hello", &e))?;
                links[j] = Some(Mutex::new(s));
            }
            for _ in rank + 1..nranks {
                let mut s = accept_deadline(&mesh_listener, deadline)
                    .map_err(|e| io(None, "waiting for higher-rank mesh peers", &e))?;
                prep(&s).map_err(|e| io(None, "configuring mesh socket", &e))?;
                let (ty, _, payload) =
                    tcp_read_frame(&mut s).map_err(|e| io(None, "reading mesh hello", &e))?;
                if ty != FRAME_MESH_HELLO {
                    return Err(io(None, "expected mesh hello, got", &frame::frame_name(ty)));
                }
                let r = Cursor::new(&payload)
                    .u32()
                    .map_err(|e| io(None, "decoding mesh hello", &e))? as usize;
                if r <= rank || r >= nranks || links[r].is_some() {
                    return Err(io(None, "mesh peer announced invalid rank", &r));
                }
                links[r] = Some(Mutex::new(s));
            }
        }
        let comm = Self::new_with_wire(rank, nranks, timeout, Wire::Tcp(links));
        // A completed barrier certifies the whole mesh end-to-end.
        comm.barrier(0).map_err(|mut e| {
            e.phase = ph;
            e
        })?;
        Ok(comm)
    }

    // ---- framed point-to-point ---------------------------------------

    /// One raw frame write on the wire — no sequencing, no injection.
    fn write_frame_once(
        &self,
        peer: usize,
        ty: u8,
        seq: u32,
        payload: &[u8],
    ) -> Result<(), String> {
        match &self.wire {
            Wire::Tcp(links) => {
                let link = links
                    .get(peer)
                    .and_then(|l| l.as_ref())
                    .ok_or_else(|| format!("no link to rank {peer}"))?;
                let mut s = link.lock().map_err(|_| "link lock poisoned".to_string())?;
                tcp_write_frame(&mut s, ty, seq, payload).map_err(|e| e.to_string())
            }
            Wire::Mem(ep) => {
                let mut f = Vec::with_capacity(payload.len() + 5);
                f.push(ty);
                f.extend_from_slice(&seq.to_le_bytes());
                f.extend_from_slice(payload);
                ep.send(peer, f)
            }
        }
    }

    /// One raw frame read off the wire — no sequencing, no heartbeat skip.
    fn read_frame_once(
        &self,
        peer: usize,
        expect: u8,
        phase: DistPhase,
    ) -> Result<(u8, u32, Vec<u8>), DistError> {
        let err = |detail: String| DistError { rank: self.rank, peer: Some(peer), phase, detail };
        match &self.wire {
            Wire::Tcp(links) => {
                let link = links
                    .get(peer)
                    .and_then(|l| l.as_ref())
                    .ok_or_else(|| err(format!("no link to rank {peer}")))?;
                let mut s = link.lock().map_err(|_| err("link lock poisoned".into()))?;
                tcp_read_frame(&mut s).map_err(|e| {
                    let kind = e.kind();
                    if kind == std::io::ErrorKind::WouldBlock || kind == std::io::ErrorKind::TimedOut
                    {
                        err(format!(
                            "timed out after {:?} waiting for a {} frame — peer dead or hung?",
                            self.timeout,
                            frame::frame_name(expect)
                        ))
                    } else {
                        err(format!(
                            "reading {} frame failed: {e} — peer likely exited",
                            frame::frame_name(expect)
                        ))
                    }
                })
            }
            Wire::Mem(ep) => {
                let f = ep.recv(peer, self.timeout).map_err(&err)?;
                if f.len() < 5 {
                    return Err(err(format!("short frame ({} bytes)", f.len())));
                }
                let ty = f[0];
                let seq = u32::from_le_bytes([f[1], f[2], f[3], f[4]]);
                Ok((ty, seq, f[5..].to_vec()))
            }
        }
    }

    fn send_frame(
        &self,
        peer: usize,
        ty: u8,
        payload: &[u8],
        phase: DistPhase,
    ) -> Result<(), DistError> {
        let err = |detail: String| DistError { rank: self.rank, peer: Some(peer), phase, detail };
        let seq = self.send_seq[peer].fetch_add(1, Ordering::Relaxed) as u32;
        // Fault injection covers steady-state traffic only: rendezvous
        // frames predate the sequenced protocol and shutdown is best-effort
        // teardown. Without an armed plan this is one atomic load.
        let fault = match phase {
            DistPhase::Rendezvous | DistPhase::Shutdown => None,
            _ => crate::fault::active().filter(|f| f.plan().has_frame_faults()),
        };
        if let Some(f) = fault {
            if let Some(d) = f.delay_frame() {
                crate::telemetry::metrics::fault_injected_total().inc();
                std::thread::sleep(d);
            }
            // An injected drop loses the frame BEFORE any bytes hit the
            // wire, and this loop is the sender's retry path: back off and
            // re-send until a draw lets the frame through. The clause's
            // probability is capped at 0.9, so the loop terminates almost
            // surely, and the peer sees exactly one copy. Injected losses
            // deliberately do NOT consume a bounded retry budget — a real
            // write error below still fails fast (retrying a partially
            // written TCP frame would corrupt the stream framing; run-level
            // recovery is `--auto-resume`).
            let mut attempt = 0u32;
            while f.drop_frame() {
                crate::telemetry::metrics::fault_injected_total().inc();
                crate::telemetry::metrics::transport_retries_total().inc();
                std::thread::sleep(crate::fault::backoff_delay(
                    attempt,
                    Duration::from_micros(50),
                    Duration::from_millis(5),
                    (self.rank as u64) << 32 | peer as u64,
                ));
                attempt = attempt.wrapping_add(1);
            }
        }
        self.write_frame_once(peer, ty, seq, payload)
            .map_err(|e| err(format!("sending {} frame failed: {e}", frame::frame_name(ty))))?;
        if let Some(f) = fault {
            if f.dup_frame() {
                // Injected duplicate: retransmit the SAME sequence number;
                // the receiver's dedup must discard it. Best-effort — a
                // failed retransmit of a duplicate is not an error.
                crate::telemetry::metrics::fault_injected_total().inc();
                let _ = self.write_frame_once(peer, ty, seq, payload);
            }
        }
        self.counters.frames_sent.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_sent.fetch_add(payload.len() as u64 + 5, Ordering::Relaxed);
        if crate::telemetry::enabled() {
            crate::telemetry::metrics::dist_frames_sent_total().inc();
            crate::telemetry::metrics::dist_bytes_sent_total().add(payload.len() as u64 + 5);
        }
        Ok(())
    }

    fn recv_frame(&self, peer: usize, expect: u8, phase: DistPhase) -> Result<Vec<u8>, DistError> {
        let err = |detail: String| DistError { rank: self.rank, peer: Some(peer), phase, detail };
        // The per-read timeout below bounds each blocking read; this
        // deadline bounds the whole call, so a peer that stays "alive" via
        // heartbeats or duplicates but never sends the expected frame still
        // trips `--dist-timeout`.
        let deadline = Instant::now() + self.timeout;
        loop {
            let (ty, seq, payload) = self.read_frame_once(peer, expect, phase)?;
            self.mark_heard(peer);
            if ty == FRAME_HEARTBEAT {
                // Liveness probe — sequence-exempt, never surfaced to callers.
                if Instant::now() >= deadline {
                    return Err(err(format!(
                        "timed out after {:?}: peer heartbeats but never sent the {} frame",
                        self.timeout,
                        frame::frame_name(expect)
                    )));
                }
                continue;
            }
            let expected = self.recv_seq[peer].load(Ordering::Relaxed) as u32;
            if seq != expected {
                if seq == expected.wrapping_sub(1) {
                    // A retransmit of the frame we already consumed
                    // (injected duplicate) — discard and read on.
                    if Instant::now() >= deadline {
                        return Err(err(format!(
                            "timed out after {:?} discarding duplicates while waiting for a {} frame",
                            self.timeout,
                            frame::frame_name(expect)
                        )));
                    }
                    continue;
                }
                return Err(err(format!(
                    "sequence gap: expected frame #{expected} from rank {peer}, got #{seq} ({}) — \
                     a frame was lost in transit",
                    frame::frame_name(ty)
                )));
            }
            self.recv_seq[peer].fetch_add(1, Ordering::Relaxed);
            self.counters.frames_recv.fetch_add(1, Ordering::Relaxed);
            self.counters.bytes_recv.fetch_add(payload.len() as u64 + 5, Ordering::Relaxed);
            if crate::telemetry::enabled() {
                crate::telemetry::metrics::dist_frames_recv_total().inc();
                crate::telemetry::metrics::dist_bytes_recv_total().add(payload.len() as u64 + 5);
            }
            if ty == FRAME_SHUTDOWN && expect != FRAME_SHUTDOWN {
                return Err(err(format!(
                    "peer shut down while this rank expected a {} frame",
                    frame::frame_name(expect)
                )));
            }
            if ty != expect {
                return Err(err(format!(
                    "protocol desync: expected {} frame, got {}",
                    frame::frame_name(expect),
                    frame::frame_name(ty)
                )));
            }
            return Ok(payload);
        }
    }

    // ---- gradient fold-reduce ----------------------------------------

    fn send_grads(&self, peer: usize, loss: f64, acc: &[Matrix]) -> Result<(), DistError> {
        for (i, g) in acc.iter().enumerate() {
            let mut p = Vec::with_capacity(12 + g.data.len() * 4);
            frame::put_u32(&mut p, i as u32);
            frame::put_matrix(&mut p, g);
            self.send_frame(peer, FRAME_GRAD_CHUNK, &p, DistPhase::AllReduce)?;
        }
        let mut p = Vec::with_capacity(8);
        frame::put_f64(&mut p, loss);
        self.send_frame(peer, FRAME_SCALARS, &p, DistPhase::AllReduce)
    }

    fn recv_grads(&self, peer: usize, n_layers: usize) -> Result<(f64, Vec<Matrix>), DistError> {
        let err = |detail: String| DistError {
            rank: self.rank,
            peer: Some(peer),
            phase: DistPhase::AllReduce,
            detail,
        };
        let mut acc = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let payload = self.recv_frame(peer, FRAME_GRAD_CHUNK, DistPhase::AllReduce)?;
            let mut c = Cursor::new(&payload);
            let layer = c.u32().map_err(&err)? as usize;
            if layer != i {
                return Err(err(format!("grad chunk out of order: expected layer {i}, got {layer}")));
            }
            acc.push(c.matrix().map_err(&err)?);
        }
        let payload = self.recv_frame(peer, FRAME_SCALARS, DistPhase::AllReduce)?;
        let loss = Cursor::new(&payload).f64().map_err(&err)?;
        Ok((loss, acc))
    }

    /// Order-preserving fold-reduce: `local` is this rank's per-microbatch
    /// `(f64 loss, grads)` list IN MICROBATCH ORDER. Returns the UNSCALED
    /// global sum (gradients and f64 loss) on every rank; the caller applies
    /// the serial `1/k` scaling. See the module docs for why this is a chain
    /// and not a ring.
    pub fn fold_all_reduce(
        &self,
        local: Vec<(f64, Vec<Matrix>)>,
        n_layers: usize,
    ) -> Result<(f64, Vec<Matrix>), DistError> {
        let t0 = Instant::now();
        let (mut loss, mut acc): (f64, Option<Vec<Matrix>>) = if self.rank == 0 {
            (0.0, None)
        } else {
            let (l, g) = self.recv_grads(self.rank - 1, n_layers)?;
            (l, Some(g))
        };
        // One microbatch at a time — pre-folding a slice would change the
        // f32 summation bracketing vs the serial fold-left.
        for (l, g) in local {
            loss += l;
            acc = Some(match acc.take() {
                None => g,
                Some(mut a) => {
                    for (x, y) in a.iter_mut().zip(&g) {
                        x.axpy_inplace(1.0, y);
                    }
                    a
                }
            });
        }
        let mut acc = acc.ok_or_else(|| {
            DistError::new(self.rank, DistPhase::AllReduce, "no microbatches to reduce")
        })?;
        let last = self.nranks - 1;
        if self.rank < last {
            self.send_grads(self.rank + 1, loss, &acc)?;
        }
        if self.rank == last {
            for r in 0..last {
                self.send_grads(r, loss, &acc)?;
            }
        } else {
            let (l, g) = self.recv_grads(last, n_layers)?;
            loss = l;
            acc = g;
        }
        let dt = t0.elapsed();
        self.counters.allreduce_nanos.fetch_add(dt.as_nanos() as u64, Ordering::Relaxed);
        if crate::telemetry::enabled() {
            crate::telemetry::metrics::dist_allreduce_seconds().observe(dt.as_secs_f64());
        }
        Ok((loss, acc))
    }

    // ---- basis broadcast ---------------------------------------------

    /// Owner side: ship one batch of publications to every peer (possibly
    /// empty — the frame count per step is part of the protocol, so peers
    /// always know to read it).
    pub fn bcast_basis(&self, entries: &[BasisEntry]) -> Result<(), DistError> {
        let payload = frame::encode_basis_batch(entries);
        for r in 0..self.nranks {
            if r != self.rank {
                self.send_frame(r, FRAME_BASIS_BATCH, &payload, DistPhase::BasisBroadcast)?;
            }
        }
        Ok(())
    }

    /// Receiver side of [`Self::bcast_basis`].
    pub fn recv_basis(&self, from: usize) -> Result<Vec<BasisEntry>, DistError> {
        let payload = self.recv_frame(from, FRAME_BASIS_BATCH, DistPhase::BasisBroadcast)?;
        frame::decode_basis_batch(&payload).map_err(|e| DistError {
            rank: self.rank,
            peer: Some(from),
            phase: DistPhase::BasisBroadcast,
            detail: format!("decoding basis batch: {e}"),
        })
    }

    // ---- barrier ------------------------------------------------------

    /// Rank-0-centric barrier: workers check in, rank 0 releases everyone.
    pub fn barrier(&self, tag: u64) -> Result<(), DistError> {
        let ph = DistPhase::Barrier;
        let err = |peer: usize, detail: String| DistError {
            rank: self.rank,
            peer: Some(peer),
            phase: ph,
            detail,
        };
        let mut payload = Vec::with_capacity(8);
        frame::put_u64(&mut payload, tag);
        if self.rank == 0 {
            for r in 1..self.nranks {
                let p = self.recv_frame(r, FRAME_BARRIER, ph)?;
                let got = Cursor::new(&p).u64().map_err(|e| err(r, e))?;
                if got != tag {
                    return Err(err(r, format!("barrier tag mismatch: expected {tag}, got {got}")));
                }
            }
            for r in 1..self.nranks {
                self.send_frame(r, FRAME_BARRIER, &payload, ph)?;
            }
        } else {
            self.send_frame(0, FRAME_BARRIER, &payload, ph)?;
            let p = self.recv_frame(0, FRAME_BARRIER, ph)?;
            let got = Cursor::new(&p).u64().map_err(|e| err(0, e))?;
            if got != tag {
                return Err(err(0, format!("barrier tag mismatch: expected {tag}, got {got}")));
            }
        }
        Ok(())
    }

    // ---- health gather -------------------------------------------------

    fn encode_health(h: &RankHealth) -> Vec<u8> {
        let mut p = Vec::with_capacity(64);
        frame::put_u32(&mut p, h.rank as u32);
        frame::put_u64(&mut p, h.owned_layers as u64);
        frame::put_u64(&mut p, h.owned_refreshes);
        frame::put_u64(&mut p, h.frames_sent);
        frame::put_u64(&mut p, h.frames_recv);
        frame::put_u64(&mut p, h.bytes_sent);
        frame::put_u64(&mut p, h.bytes_recv);
        frame::put_f64(&mut p, h.allreduce_s);
        p
    }

    fn decode_health(p: &[u8]) -> Result<RankHealth, String> {
        let mut c = Cursor::new(p);
        Ok(RankHealth {
            rank: c.u32()? as usize,
            owned_layers: c.u64()? as usize,
            owned_refreshes: c.u64()?,
            frames_sent: c.u64()?,
            frames_recv: c.u64()?,
            bytes_sent: c.u64()?,
            bytes_recv: c.u64()?,
            allreduce_s: c.f64()?,
        })
    }

    /// Collective on the metrics cadence: every rank contributes its row;
    /// rank 0 gets the full rank-ordered table (`Ok(Some(...))`), workers get
    /// `Ok(None)`. EVERY rank must call this at the same step — participation
    /// cannot depend on sink presence (workers have no sinks).
    pub fn gather_health(&self, local: &RankHealth) -> Result<Option<Vec<RankHealth>>, DistError> {
        let ph = DistPhase::HealthGather;
        if self.rank == 0 {
            let mut out = Vec::with_capacity(self.nranks);
            out.push(local.clone());
            for r in 1..self.nranks {
                let p = self.recv_frame(r, FRAME_HEALTH, ph)?;
                let h = Self::decode_health(&p).map_err(|e| DistError {
                    rank: self.rank,
                    peer: Some(r),
                    phase: ph,
                    detail: format!("decoding health row: {e}"),
                })?;
                out.push(h);
            }
            Ok(Some(out))
        } else {
            self.send_frame(0, FRAME_HEALTH, &Self::encode_health(local), ph)?;
            Ok(None)
        }
    }

    // ---- heartbeat -----------------------------------------------------

    fn mark_heard(&self, peer: usize) {
        let ms = self.epoch.elapsed().as_millis() as u64;
        self.last_heard[peer].store(ms, Ordering::Relaxed);
    }

    /// Longest silence across peers: time since anything — heartbeat or
    /// data — was last read from the quietest peer.
    pub fn max_peer_silence(&self) -> Duration {
        let now = self.epoch.elapsed().as_millis() as u64;
        let mut worst = 0u64;
        for (peer, heard) in self.last_heard.iter().enumerate() {
            if peer == self.rank {
                continue;
            }
            worst = worst.max(now.saturating_sub(heard.load(Ordering::Relaxed)));
        }
        Duration::from_millis(worst)
    }

    /// Spawn the background liveness monitor: every `timeout/4` it writes a
    /// [`FRAME_HEARTBEAT`] probe to each idle peer link and refreshes the
    /// silence gauge, so a dead peer surfaces within `--dist-timeout` even
    /// across long quiet stretches (a worker stuck in a slow refresh no
    /// longer looks identical to a dead one in the metrics). The thread
    /// holds only a `Weak` reference and exits on its next tick after the
    /// communicator is dropped. TCP only — the mem transport's "peers" are
    /// threads in this process and its channel reads are already bounded.
    pub fn start_heartbeat(this: &Arc<Self>) {
        if !matches!(this.wire, Wire::Tcp(_)) {
            return;
        }
        let period = (this.timeout / 4)
            .clamp(Duration::from_millis(10), Duration::from_secs(2));
        let weak = Arc::downgrade(this);
        let _ = std::thread::Builder::new()
            .name(format!("soap-heartbeat-r{}", this.rank))
            .spawn(move || loop {
                std::thread::sleep(period);
                let Some(comm) = weak.upgrade() else { return };
                comm.heartbeat_tick();
            });
    }

    fn heartbeat_tick(&self) {
        let Wire::Tcp(links) = &self.wire else { return };
        for link in links.iter().flatten() {
            // try_lock only: if the main thread holds the link it is mid-
            // collective, which is itself proof this side is alive — never
            // stall the hot path for a probe. Write errors are ignored;
            // the protocol path owns dead-peer reporting.
            if let Ok(mut s) = link.try_lock() {
                if tcp_write_frame(&mut s, FRAME_HEARTBEAT, HEARTBEAT_SEQ, &[]).is_ok() {
                    crate::telemetry::metrics::heartbeats_sent_total().inc();
                }
            }
        }
        if crate::telemetry::enabled() {
            crate::telemetry::metrics::heartbeat_silence_seconds()
                .set(self.max_peer_silence().as_secs_f64());
        }
    }

    // ---- teardown ------------------------------------------------------

    /// Best-effort shutdown notice to every peer (errors ignored — peers may
    /// already be gone).
    pub fn shutdown(&self) {
        for r in 0..self.nranks {
            if r != self.rank {
                let _ = self.send_frame(r, FRAME_SHUTDOWN, &[], DistPhase::Shutdown);
            }
        }
    }

    /// Instance traffic counters:
    /// `(frames_sent, frames_recv, bytes_sent, bytes_recv, allreduce_seconds)`.
    pub fn traffic(&self) -> (u64, u64, u64, u64, f64) {
        (
            self.counters.frames_sent.load(Ordering::Relaxed),
            self.counters.frames_recv.load(Ordering::Relaxed),
            self.counters.bytes_sent.load(Ordering::Relaxed),
            self.counters.bytes_recv.load(Ordering::Relaxed),
            self.counters.allreduce_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::MemCluster;
    use std::sync::Arc;

    fn mem_comms(n: usize) -> Vec<Arc<DistComm>> {
        MemCluster::new(n)
            .into_iter()
            .map(|ep| Arc::new(DistComm::connect_mem(ep, Duration::from_secs(5)).unwrap()))
            .collect()
    }

    #[test]
    fn microbatch_slices_cover_contiguously() {
        for &(n, k) in &[(2usize, 4usize), (2, 5), (3, 4), (4, 4), (4, 2), (3, 1)] {
            let mut next = 0;
            for r in 0..n {
                let (start, count) = microbatch_slice(r, n, k);
                assert_eq!(start, next, "slice for rank {r}/{n} over {k} not contiguous");
                next += count;
            }
            assert_eq!(next, k, "slices for {n} ranks over {k} microbatches don't cover");
        }
    }

    #[test]
    fn fold_all_reduce_matches_serial_fold() {
        let n = 3;
        // 5 microbatches: ranks get slices [0,2) [2,4) [4,5).
        let mbs: Vec<(f64, Vec<Matrix>)> = (0..5)
            .map(|i| {
                let g = Matrix::from_vec(2, 2, vec![0.1 * i as f32, 1.0 / (i + 1) as f32, -0.3, 2.0]);
                (0.25 * i as f64, vec![g])
            })
            .collect();
        // Serial reference: strict fold-left.
        let mut serial = mbs[0].1[0].clone();
        let mut serial_loss = mbs[0].0;
        for (l, g) in &mbs[1..] {
            serial.axpy_inplace(1.0, &g[0]);
            serial_loss += l;
        }
        let comms = mem_comms(n);
        let handles: Vec<_> = comms
            .iter()
            .map(|comm| {
                let comm = Arc::clone(comm);
                let mbs = mbs.clone();
                std::thread::spawn(move || {
                    let (start, count) = microbatch_slice(comm.rank(), comm.nranks(), mbs.len());
                    let local = mbs[start..start + count].to_vec();
                    comm.fold_all_reduce(local, 1).unwrap()
                })
            })
            .collect();
        for h in handles {
            let (loss, acc) = h.join().unwrap();
            assert_eq!(loss.to_bits(), serial_loss.to_bits());
            for (a, b) in acc[0].data.iter().zip(&serial.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "distributed sum diverged from serial fold");
            }
        }
    }

    #[test]
    fn barrier_and_health_gather() {
        let comms = mem_comms(2);
        let c1 = Arc::clone(&comms[1]);
        let t = std::thread::spawn(move || {
            c1.barrier(7).unwrap();
            let local = RankHealth { rank: 1, owned_layers: 3, ..RankHealth::new(1) };
            assert!(c1.gather_health(&local).unwrap().is_none());
        });
        comms[0].barrier(7).unwrap();
        let local = RankHealth { rank: 0, owned_layers: 2, ..RankHealth::new(0) };
        let rows = comms[0].gather_health(&local).unwrap().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].rank, 0);
        assert_eq!(rows[1].rank, 1);
        assert_eq!(rows[1].owned_layers, 3);
        t.join().unwrap();
        let (fs, fr, bs, br, _) = comms[0].traffic();
        assert!(fs > 0 && fr > 0 && bs > 0 && br > 0, "traffic counters never moved");
    }

    /// Handcraft a mem-wire frame with an explicit sequence number.
    fn raw_frame(ty: u8, seq: u32, payload: &[u8]) -> Vec<u8> {
        let mut f = vec![ty];
        f.extend_from_slice(&seq.to_le_bytes());
        f.extend_from_slice(payload);
        f
    }

    #[test]
    fn duplicate_frames_are_discarded() {
        let mut eps = MemCluster::new(2);
        let ep1 = eps.pop().unwrap();
        let comm0 = DistComm::connect_mem(eps.pop().unwrap(), Duration::from_millis(500)).unwrap();
        let mut tag = Vec::new();
        frame::put_u64(&mut tag, 7);
        // Frame 0 retransmitted (same seq), then frame 1: the receiver must
        // consume exactly two distinct frames.
        ep1.send(0, raw_frame(FRAME_BARRIER, 0, &tag)).unwrap();
        ep1.send(0, raw_frame(FRAME_BARRIER, 0, &tag)).unwrap();
        ep1.send(0, raw_frame(FRAME_HEALTH, 1, &[])).unwrap();
        let p = comm0.recv_frame(1, FRAME_BARRIER, DistPhase::Barrier).unwrap();
        assert_eq!(Cursor::new(&p).u64().unwrap(), 7);
        let p = comm0.recv_frame(1, FRAME_HEALTH, DistPhase::HealthGather).unwrap();
        assert!(p.is_empty(), "duplicate leaked through as a distinct frame");
    }

    #[test]
    fn heartbeats_are_skipped_and_sequence_exempt() {
        let mut eps = MemCluster::new(2);
        let ep1 = eps.pop().unwrap();
        let comm0 = DistComm::connect_mem(eps.pop().unwrap(), Duration::from_millis(500)).unwrap();
        ep1.send(0, raw_frame(FRAME_HEARTBEAT, HEARTBEAT_SEQ, &[])).unwrap();
        let mut tag = Vec::new();
        frame::put_u64(&mut tag, 3);
        ep1.send(0, raw_frame(FRAME_BARRIER, 0, &tag)).unwrap();
        let p = comm0.recv_frame(1, FRAME_BARRIER, DistPhase::Barrier).unwrap();
        assert_eq!(Cursor::new(&p).u64().unwrap(), 3);
        assert!(comm0.max_peer_silence() < Duration::from_secs(1));
    }

    #[test]
    fn sequence_gap_is_a_typed_error() {
        let mut eps = MemCluster::new(2);
        let ep1 = eps.pop().unwrap();
        let comm0 = DistComm::connect_mem(eps.pop().unwrap(), Duration::from_millis(500)).unwrap();
        // Frame #0 never arrives; #5 shows up instead.
        ep1.send(0, raw_frame(FRAME_BARRIER, 5, &[])).unwrap();
        let err = comm0.recv_frame(1, FRAME_BARRIER, DistPhase::Barrier).unwrap_err();
        assert!(err.to_string().contains("sequence gap"), "{err}");
        assert_eq!(err.peer, Some(1));
    }

    #[test]
    fn dead_peer_trips_timeout_not_hang() {
        let mut eps = MemCluster::new(2);
        let ep0 = eps.remove(0);
        drop(eps); // rank 1 never comes up — its endpoints are dropped
        let comm = DistComm::connect_mem(ep0, Duration::from_millis(50)).unwrap();
        let err = comm.barrier(1).unwrap_err();
        assert_eq!(err.rank, 0);
        assert_eq!(err.phase, DistPhase::Barrier);
        assert!(err.to_string().contains("hung up"), "{err}");
    }

    #[test]
    fn rendezvous_times_out_when_worker_never_connects() {
        // Coordinator side of the TCP rendezvous with a worker that never
        // dials in: the accept loop must surface a typed error within
        // --dist-timeout, not hang waiting forever.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t0 = Instant::now();
        let err =
            DistComm::connect_tcp(0, 2, &addr, Some(listener), Duration::from_millis(100), 1)
                .unwrap_err();
        assert_eq!(err.rank, 0);
        assert_eq!(err.phase, DistPhase::Rendezvous);
        assert!(err.to_string().contains("waiting for workers"), "{err}");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "accept loop overshot the deadline: {:?}",
            t0.elapsed()
        );
    }
}
