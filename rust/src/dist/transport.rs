//! Transports for the distributed executor.
//!
//! - [`Transport::Tcp`] — localhost sockets between real processes (the
//!   production shape; what `--backend distributed` self-spawn uses). Frames
//!   are `[u32 len][u8 type][u32 seq][payload]`, streams run with
//!   `TCP_NODELAY` and a read timeout so a dead peer surfaces as a typed
//!   error instead of a hang.
//! - [`Transport::Mem`] — an in-process `mpsc` channel mesh
//!   ([`MemCluster`]), one thread per rank. Same frames minus the length
//!   prefix (channels preserve message boundaries). This is what the golden
//!   tests use to run real multi-rank protocols inside one test process.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::Result;

/// Which wire the distributed backend runs over. Parsed from
/// `--dist-transport` / the `dist_transport` config key.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    /// Localhost TCP between worker processes (default).
    Tcp,
    /// In-process channel mesh between worker threads (tests, single-process
    /// experiments).
    Mem,
}

/// Transport names accepted by [`Transport::parse`], embedded in errors.
pub const TRANSPORT_NAMES: &str = "tcp, mem";

impl Transport {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "tcp" => Transport::Tcp,
            "mem" | "memory" | "shm" => Transport::Mem,
            other => {
                anyhow::bail!("unknown dist transport '{other}': expected one of {TRANSPORT_NAMES}")
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Transport::Tcp => "tcp",
            Transport::Mem => "mem",
        }
    }
}

// ---- TCP framing ---------------------------------------------------------

/// Write one `[u32 len][u8 type][u32 seq][payload]` frame. `len` counts the
/// type byte, sequence number, and payload so a reader can always pre-size
/// its buffer. `seq` is the comm layer's per-link counter (0 during
/// rendezvous, before the sequenced protocol starts).
pub fn tcp_write_frame(
    stream: &mut TcpStream,
    ty: u8,
    seq: u32,
    payload: &[u8],
) -> std::io::Result<()> {
    let len = (payload.len() + 5) as u32;
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(&[ty])?;
    stream.write_all(&seq.to_le_bytes())?;
    stream.write_all(payload)?;
    Ok(())
}

/// Read one frame; returns `(type, seq, payload)`. A peer that died
/// mid-frame shows up as an io error (timeout or unexpected EOF) for the
/// comm layer to wrap with rank/phase context.
pub fn tcp_read_frame(stream: &mut TcpStream) -> std::io::Result<(u8, u32, Vec<u8>)> {
    let mut len_bytes = [0u8; 4];
    stream.read_exact(&mut len_bytes)?;
    let len = u32::from_le_bytes(len_bytes) as usize;
    if len < 5 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("short frame header ({len} bytes)"),
        ));
    }
    let mut head = [0u8; 5];
    stream.read_exact(&mut head)?;
    let ty = head[0];
    let seq = u32::from_le_bytes([head[1], head[2], head[3], head[4]]);
    let mut buf = vec![0u8; len - 5];
    stream.read_exact(&mut buf)?;
    Ok((ty, seq, buf))
}

/// Accept one connection with a deadline: `TcpListener::accept` has no
/// native timeout, so the listener runs nonblocking and polls.
pub fn accept_deadline(listener: &TcpListener, deadline: Instant) -> std::io::Result<TcpStream> {
    listener.set_nonblocking(true)?;
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                return Ok(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "timed out waiting for a peer to connect",
                    ));
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e),
        }
    }
}

/// Dial with exponential-backoff retry until a deadline (a manually launched
/// worker may start before the coordinator's listener is up). Backoff delays
/// come from [`crate::fault::backoff_delay`] — bounded, jittered per address
/// so a gang of workers doesn't re-dial in lockstep, and capped at 250 ms so
/// a late listener is still picked up promptly.
pub fn connect_deadline(addr: &str, deadline: Instant) -> std::io::Result<TcpStream> {
    let seed = addr.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ u64::from(b)).wrapping_mul(0x1000_0000_01b3)
    });
    let mut attempt = 0u32;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        format!("could not reach {addr}: {e}"),
                    ));
                }
                let delay = crate::fault::backoff_delay(
                    attempt,
                    Duration::from_millis(2),
                    Duration::from_millis(250),
                    seed,
                );
                // Never sleep past the deadline itself.
                let left = deadline.saturating_duration_since(Instant::now());
                std::thread::sleep(delay.min(left));
                attempt = attempt.wrapping_add(1);
            }
        }
    }
}

// ---- in-process channel mesh ---------------------------------------------

/// One rank's two directed channels to one peer.
pub struct MemPeer {
    pub tx: Mutex<Sender<Vec<u8>>>,
    pub rx: Mutex<Receiver<Vec<u8>>>,
}

/// One rank's endpoint of a [`MemCluster`]: directed channels to every other
/// rank (`peers[self_rank]` is `None`).
pub struct MemEndpoint {
    pub rank: usize,
    pub nranks: usize,
    pub peers: Vec<Option<MemPeer>>,
}

impl MemEndpoint {
    pub fn send(&self, peer: usize, frame: Vec<u8>) -> Result<(), String> {
        let p = self.peers[peer].as_ref().ok_or("no channel to self")?;
        p.tx.lock()
            .map_err(|_| "mem transport lock poisoned".to_string())?
            .send(frame)
            .map_err(|_| format!("peer {peer} hung up (channel closed)"))
    }

    pub fn recv(&self, peer: usize, timeout: Duration) -> Result<Vec<u8>, String> {
        let p = self.peers[peer].as_ref().ok_or("no channel to self")?;
        let rx = p.rx.lock().map_err(|_| "mem transport lock poisoned".to_string())?;
        rx.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => format!("timed out waiting on peer {peer}"),
            RecvTimeoutError::Disconnected => format!("peer {peer} hung up (channel closed)"),
        })
    }
}

/// Build the full `n`-rank channel mesh and split it into per-rank
/// endpoints — hand each to a worker thread.
pub struct MemCluster;

impl MemCluster {
    pub fn new(n: usize) -> Vec<MemEndpoint> {
        assert!(n >= 2, "a mem cluster needs at least 2 ranks");
        // senders[i][j] carries i → j traffic; receivers[j][i] is its sink.
        let mut txs: Vec<Vec<Option<Sender<Vec<u8>>>>> = (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        let mut rxs: Vec<Vec<Option<Receiver<Vec<u8>>>>> = (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let (tx, rx) = channel();
                txs[i][j] = Some(tx);
                rxs[j][i] = Some(rx);
            }
        }
        let mut endpoints = Vec::with_capacity(n);
        for (rank, (tx_row, rx_row)) in txs.into_iter().zip(rxs).enumerate() {
            let peers = tx_row
                .into_iter()
                .zip(rx_row)
                .map(|(tx, rx)| match (tx, rx) {
                    (Some(tx), Some(rx)) => {
                        Some(MemPeer { tx: Mutex::new(tx), rx: Mutex::new(rx) })
                    }
                    _ => None,
                })
                .collect();
            endpoints.push(MemEndpoint { rank, nranks: n, peers });
        }
        endpoints
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_parse_and_names() {
        assert_eq!(Transport::parse("tcp").unwrap(), Transport::Tcp);
        assert_eq!(Transport::parse("MEM").unwrap(), Transport::Mem);
        let e = Transport::parse("infiniband").unwrap_err().to_string();
        assert!(e.contains("tcp") && e.contains("mem"), "{e}");
    }

    #[test]
    fn mem_cluster_routes_between_ranks() {
        let mut eps = MemCluster::new(3);
        let c = eps.pop().unwrap();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        a.send(1, vec![1, 2, 3]).unwrap();
        a.send(2, vec![9]).unwrap();
        assert_eq!(b.recv(0, Duration::from_secs(1)).unwrap(), vec![1, 2, 3]);
        assert_eq!(c.recv(0, Duration::from_secs(1)).unwrap(), vec![9]);
        c.send(1, vec![7]).unwrap();
        assert_eq!(b.recv(2, Duration::from_secs(1)).unwrap(), vec![7]);
        // A rank that never sends trips the timeout, not a hang.
        let err = b.recv(2, Duration::from_millis(20)).unwrap_err();
        assert!(err.contains("timed out"), "{err}");
    }

    #[test]
    fn tcp_frames_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            tcp_write_frame(&mut s, 4, 17, &[10, 20, 30]).unwrap();
            tcp_write_frame(&mut s, 6, 18, &[]).unwrap();
        });
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut s = accept_deadline(&listener, deadline).unwrap();
        let (ty, seq, payload) = tcp_read_frame(&mut s).unwrap();
        assert_eq!((ty, seq, payload), (4, 17, vec![10, 20, 30]));
        let (ty, seq, payload) = tcp_read_frame(&mut s).unwrap();
        assert_eq!((ty, seq), (6, 18));
        assert!(payload.is_empty());
        t.join().unwrap();
    }

    #[test]
    fn connect_deadline_gives_up_within_budget() {
        // Grab a port, then close the listener so nothing is dialable there.
        let dead_addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let start = Instant::now();
        let err =
            connect_deadline(&dead_addr, Instant::now() + Duration::from_millis(150)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
        assert!(
            start.elapsed() < Duration::from_secs(2),
            "backoff overshot the deadline: {:?}",
            start.elapsed()
        );
    }
}
