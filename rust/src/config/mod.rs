//! Experiment/run configuration: a typed layer over the CLI (and the
//! `key=value` config files the launcher accepts), translating user intent
//! into a `session::SessionBuilder` + model/artifact choices.

pub mod run;

pub use run::{RunConfig, DEFAULT_LRS};
