//! Experiment/run configuration: a typed layer over the CLI (and the INI-ish
//! config files the launcher accepts), translating user intent into
//! `TrainerConfig` + model/artifact choices.

pub mod run;

pub use run::{RunConfig, DEFAULT_LRS};
