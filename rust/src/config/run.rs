//! Run configuration: the launcher surface. Parses CLI options and
//! `key=value` config files into a validated run description, owns the
//! paper-default hyperparameter policy (Appendix A), and maps onto the
//! typed [`SessionBuilder`] — the one seam where the whole configuration is
//! validated.
//!
//! Precedence is defaults < `--config` file < explicit CLI arguments.
//! `--dump-config` emits the CONFIGURATION subset of the key set
//! [`RunConfig::apply_kv`] accepts — run actions (`save`/`resume`) and the
//! legacy flag aliases (`refresh-eigh`/`async-refresh`/`pjrt-optimizer`,
//! already folded into their named forms) are intentionally not dumped —
//! and that subset round-trips losslessly (identical [`Hyper`], identical
//! session).

use std::time::Duration;

use crate::coordinator::TrainerConfig;
use crate::dist::Transport;
use crate::optim::{
    FreqSchedule, GuardPolicy, Hyper, OptKind, RefreshMethod, RefreshMode, Schedule, StateDtype,
};
use crate::session::{Backend, DistEndpoint, DistOptions, ModelSpec, SessionBuilder, TrainSession};
use crate::util::cli::Args;

/// The learning-rate sweep grid of Appendix A: {.1, .0316, .01, …, 3.16e-4}.
pub const DEFAULT_LRS: [f32; 6] = [0.1, 0.0316, 0.01, 0.00316, 0.001, 0.000316];

/// Config keys carrying a value, shared between the CLI option set and the
/// `--config` file format (embedded in unknown-key errors).
pub const CONFIG_KEYS: &str = "model, optimizer, backend, lr, steps, warmup, seed, \
precond-freq, grad-accum, workers, refresh-workers, refresh-method, refresh-mode, \
max-precond-dim, merge-dims, adam-warmup, precond-warmup, state-dtype, ranks, rank, \
coordinator-addr, dist-timeout, dist-transport, artifacts, log-every, \
metrics-every, trace-out, metrics-out, jsonl-out, save, resume, guard, \
fault-plan, auto-resume, fault-attempt, one-sided, factorized, precondition-1d, \
refresh-eigh, async-refresh, pjrt-optimizer, telemetry";

const VALUE_KEYS: [&str; 35] = [
    "model",
    "optimizer",
    "backend",
    "lr",
    "steps",
    "warmup",
    "seed",
    "precond-freq",
    "grad-accum",
    "workers",
    "refresh-workers",
    "refresh-method",
    "refresh-mode",
    "max-precond-dim",
    "merge-dims",
    "adam-warmup",
    "precond-warmup",
    "state-dtype",
    "ranks",
    "rank",
    "coordinator-addr",
    "dist-timeout",
    "dist-transport",
    "artifacts",
    "log-every",
    "metrics-every",
    "trace-out",
    "metrics-out",
    "jsonl-out",
    "save",
    "resume",
    "guard",
    "fault-plan",
    "auto-resume",
    "fault-attempt",
];

const FLAG_KEYS: [&str; 7] = [
    "one-sided",
    "factorized",
    "precondition-1d",
    "refresh-eigh",
    "async-refresh",
    "pjrt-optimizer",
    "telemetry",
];

/// A fully-resolved run description.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: String,
    pub optimizer: OptKind,
    /// Optimizer executor: serial | sharded | pjrt.
    pub backend: Backend,
    pub lr: f32,
    pub steps: u64,
    pub warmup: u64,
    pub seed: u64,
    pub precond_freq: u64,
    /// Piecewise preconditioning-frequency schedule. Canonical invariant:
    /// when set, it covers step 0 and `precond_freq` equals its step-0
    /// frequency (`apply_kv` normalizes both), so the dump round-trips.
    pub precond_freq_schedule: Option<FreqSchedule>,
    pub grad_accum: usize,
    pub workers: usize,
    pub one_sided: bool,
    pub factorized: bool,
    /// Precondition rank-1 params instead of the AdamW fallback
    /// (`Hyper::precondition_1d`).
    pub precondition_1d: bool,
    pub refresh_eigh: bool,
    /// Run eigenbasis/inverse-root refreshes on the background service
    /// instead of the optimizer hot path (`precond::RefreshService`).
    pub async_refresh: bool,
    /// Worker threads for the async refresh service.
    pub refresh_workers: usize,
    /// Dimensions larger than this keep Q = identity (per mode for rank-3+
    /// tensors; `== cap` is still preconditioned).
    pub max_precond_dim: usize,
    /// Adjacent-mode merge threshold for rank-3+ tensors (0 = off).
    pub merge_dims: usize,
    /// Pure-Adam ramp: steps before any eigenbasis initializes/refreshes
    /// (`Hyper::adam_warmup_steps`; 0 = off).
    pub adam_warmup: u64,
    /// Refresh-every-step early phase (`Hyper::precondition_warmup`; 0 = off).
    pub precond_warmup: u64,
    /// Storage dtype for the second-moment state (Kronecker-factor EMAs,
    /// Adam/Adafactor second moments): f32 (default) or bf16
    /// (`Hyper::state_dtype`).
    pub state_dtype: StateDtype,
    /// World size for `--backend distributed` (≥ 2).
    pub ranks: usize,
    /// Manual-launch worker mode: this process's rank (requires
    /// `coordinator-addr`). Unset = coordinator, which self-spawns workers.
    pub dist_rank: Option<usize>,
    /// Rendezvous address a manually launched worker dials.
    pub coordinator_addr: Option<String>,
    /// Peer-failure timeout for distributed collectives, milliseconds.
    pub dist_timeout_ms: u64,
    /// Distributed wire (`tcp` only from the CLI; `mem` is API-only).
    pub dist_transport: Transport,
    pub artifacts_dir: String,
    pub log_every: u64,
    /// Master telemetry switch: span tracing, the metrics registry, and
    /// per-layer health snapshots every `metrics_every` steps.
    pub telemetry: bool,
    /// Health-snapshot cadence in steps (0 = never; only with telemetry).
    pub metrics_every: u64,
    /// Write a Chrome trace-event JSON here after the run (empty = none).
    pub trace_out: Option<String>,
    /// Write a Prometheus text-exposition snapshot of the metrics registry
    /// here after the run (empty = none).
    pub metrics_out: Option<String>,
    /// Stream one JSON object per step (and per health snapshot, with
    /// telemetry on) to this file (empty = none).
    pub jsonl_out: Option<String>,
    /// Resume from this checkpoint at build time (empty = fresh run).
    pub resume: Option<String>,
    /// Write a checkpoint here after the run (empty = none).
    pub save: Option<String>,
    /// Non-finite gradient/direction response (`Hyper::guard`).
    pub guard: GuardPolicy,
    /// Seeded fault-injection plan (`crate::fault::FaultPlan` grammar;
    /// empty = none). Chaos testing only — never set on production runs.
    pub fault_plan: Option<String>,
    /// On a distributed peer failure, relaunch the workers from rank 0's
    /// abort checkpoint up to this many times (0 = fail fast).
    pub auto_resume: u32,
    /// Which auto-resume relaunch this process is (0 = first attempt).
    /// Internal plumbing — the coordinator appends it to relaunched worker
    /// argv so one-shot fault clauses don't re-fire every attempt.
    pub fault_attempt: u32,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            model: "nano".into(),
            optimizer: OptKind::Soap,
            backend: Backend::Sharded,
            lr: 3e-3,
            steps: 200,
            warmup: 0,
            seed: 0,
            precond_freq: 10,
            precond_freq_schedule: None,
            grad_accum: 1,
            workers: 4,
            one_sided: false,
            factorized: false,
            precondition_1d: false,
            refresh_eigh: false,
            async_refresh: false,
            refresh_workers: 2,
            max_precond_dim: 4096,
            merge_dims: 0,
            adam_warmup: 0,
            precond_warmup: 0,
            state_dtype: StateDtype::F32,
            ranks: 2,
            dist_rank: None,
            coordinator_addr: None,
            dist_timeout_ms: 30_000,
            dist_transport: Transport::Tcp,
            artifacts_dir: "artifacts".into(),
            log_every: 10,
            telemetry: false,
            metrics_every: 10,
            trace_out: None,
            metrics_out: None,
            jsonl_out: None,
            resume: None,
            save: None,
            guard: GuardPolicy::SkipStep,
            fault_plan: None,
            auto_resume: 0,
            fault_attempt: 0,
        }
    }
}

fn parse_bool(key: &str, v: &str) -> anyhow::Result<bool> {
    match v.to_ascii_lowercase().as_str() {
        "true" | "1" | "yes" | "on" => Ok(true),
        "false" | "0" | "no" | "off" => Ok(false),
        _ => anyhow::bail!("{key}={v}: expected true/false"),
    }
}

impl RunConfig {
    /// Apply one `key=value` setting (the shared vocabulary of the CLI
    /// options and the `--config` file). Unknown keys error and enumerate
    /// the valid set.
    pub fn apply_kv(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        fn num<T: std::str::FromStr>(key: &str, v: &str) -> anyhow::Result<T>
        where
            T::Err: std::fmt::Display,
        {
            v.parse::<T>().map_err(|e| anyhow::anyhow!("{key}={v}: {e}"))
        }
        match key {
            "model" => self.model = value.to_string(),
            "optimizer" => self.optimizer = OptKind::parse(value)?,
            "backend" => self.backend = Backend::parse(value)?,
            "lr" => self.lr = num(key, value)?,
            "steps" => self.steps = num(key, value)?,
            "warmup" => self.warmup = num(key, value)?,
            "seed" => self.seed = num(key, value)?,
            "precond-freq" => {
                if value.contains('@') {
                    let parsed = FreqSchedule::parse(value)
                        .map_err(|e| anyhow::anyhow!("precond-freq: {e:#}"))?;
                    // Normalize to a schedule covering step 0 (fall back to
                    // the current base for the uncovered prefix), keeping
                    // `precond_freq` equal to the step-0 frequency so the
                    // stagger math and the dump round-trip stay consistent.
                    let sched = if parsed.freq_at(0).is_some() {
                        parsed
                    } else {
                        let mut pieces = vec![(0, self.precond_freq)];
                        pieces.extend_from_slice(parsed.pieces());
                        FreqSchedule::new(&pieces)?
                    };
                    match sched.pieces() {
                        [(0, f)] => {
                            self.precond_freq = *f;
                            self.precond_freq_schedule = None;
                        }
                        _ => {
                            self.precond_freq =
                                sched.freq_at(0).expect("schedule covers step 0");
                            self.precond_freq_schedule = Some(sched);
                        }
                    }
                } else {
                    self.precond_freq = num(key, value)?;
                    self.precond_freq_schedule = None;
                }
            }
            "grad-accum" => self.grad_accum = num(key, value)?,
            "workers" => self.workers = num(key, value)?,
            "refresh-workers" => self.refresh_workers = num(key, value)?,
            "refresh-method" => {
                self.refresh_eigh = RefreshMethod::parse(value)? == RefreshMethod::Eigh;
            }
            "refresh-mode" => {
                self.async_refresh = RefreshMode::parse(value)? == RefreshMode::Async;
            }
            "max-precond-dim" => self.max_precond_dim = num(key, value)?,
            "merge-dims" => self.merge_dims = num(key, value)?,
            "adam-warmup" => self.adam_warmup = num(key, value)?,
            "precond-warmup" => self.precond_warmup = num(key, value)?,
            "state-dtype" => self.state_dtype = StateDtype::parse(value)?,
            "ranks" => self.ranks = num(key, value)?,
            "rank" => self.dist_rank = Some(num(key, value)?),
            "coordinator-addr" => {
                self.coordinator_addr = (!value.is_empty()).then(|| value.to_string());
            }
            "dist-timeout" => self.dist_timeout_ms = num(key, value)?,
            "dist-transport" => self.dist_transport = Transport::parse(value)?,
            "artifacts" => self.artifacts_dir = value.to_string(),
            "log-every" => self.log_every = num(key, value)?,
            "metrics-every" => self.metrics_every = num(key, value)?,
            "trace-out" => self.trace_out = (!value.is_empty()).then(|| value.to_string()),
            "metrics-out" => self.metrics_out = (!value.is_empty()).then(|| value.to_string()),
            "jsonl-out" => self.jsonl_out = (!value.is_empty()).then(|| value.to_string()),
            "save" => self.save = (!value.is_empty()).then(|| value.to_string()),
            "resume" => self.resume = (!value.is_empty()).then(|| value.to_string()),
            "guard" => self.guard = GuardPolicy::parse(value)?,
            "fault-plan" => self.fault_plan = (!value.is_empty()).then(|| value.to_string()),
            "auto-resume" => self.auto_resume = num(key, value)?,
            "fault-attempt" => self.fault_attempt = num(key, value)?,
            "telemetry" => self.telemetry = parse_bool(key, value)?,
            "one-sided" => self.one_sided = parse_bool(key, value)?,
            "factorized" => self.factorized = parse_bool(key, value)?,
            "precondition-1d" => self.precondition_1d = parse_bool(key, value)?,
            "refresh-eigh" => self.refresh_eigh = parse_bool(key, value)?,
            "async-refresh" => self.async_refresh = parse_bool(key, value)?,
            "pjrt-optimizer" => {
                if parse_bool(key, value)? {
                    self.backend = Backend::Pjrt;
                }
            }
            other => anyhow::bail!("unknown config key '{other}': expected one of {CONFIG_KEYS}"),
        }
        Ok(())
    }

    /// Apply a `--config` file body: one `key=value` per line, `#` comments
    /// and blank lines ignored. Errors carry the line number.
    pub fn apply_kv_text(&mut self, text: &str) -> anyhow::Result<()> {
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("config line {}: '{line}' is not key=value", lineno + 1)
            })?;
            self.apply_kv(k.trim(), v.trim())
                .map_err(|e| anyhow::anyhow!("config line {}: {e:#}", lineno + 1))?;
        }
        Ok(())
    }

    /// Serialize the resolved CONFIGURATION as a `--config`-loadable file.
    /// Round-trip guarantee: `RunConfig::default().apply_kv_text(&rc.dump())`
    /// reproduces `rc`'s configuration (same [`Hyper`], same session),
    /// covered by tests. Run actions (`save`/`resume`) are deliberately not
    /// dumped — pass them per invocation.
    pub fn dump(&self) -> String {
        let mut s = String::from(
            "# soap-lab run config — load with `soap-lab train --config <file>`;\n\
             # explicit CLI arguments override these values.\n",
        );
        s.push_str(&format!("model={}\n", self.model));
        s.push_str(&format!("optimizer={}\n", self.optimizer.spec_string()));
        s.push_str(&format!("backend={}\n", self.backend.name()));
        s.push_str(&format!("lr={}\n", self.lr));
        s.push_str(&format!("steps={}\n", self.steps));
        s.push_str(&format!("warmup={}\n", self.warmup));
        s.push_str(&format!("seed={}\n", self.seed));
        match &self.precond_freq_schedule {
            Some(sched) => s.push_str(&format!("precond-freq={}\n", sched.spec_string(','))),
            None => s.push_str(&format!("precond-freq={}\n", self.precond_freq)),
        }
        s.push_str(&format!("grad-accum={}\n", self.grad_accum));
        s.push_str(&format!("workers={}\n", self.workers));
        s.push_str(&format!("refresh-workers={}\n", self.refresh_workers));
        s.push_str(&format!(
            "refresh-method={}\n",
            if self.refresh_eigh { RefreshMethod::Eigh } else { RefreshMethod::QrPowerIteration }
                .name()
        ));
        s.push_str(&format!(
            "refresh-mode={}\n",
            if self.async_refresh { RefreshMode::Async } else { RefreshMode::Inline }.name()
        ));
        s.push_str(&format!("max-precond-dim={}\n", self.max_precond_dim));
        s.push_str(&format!("merge-dims={}\n", self.merge_dims));
        s.push_str(&format!("adam-warmup={}\n", self.adam_warmup));
        s.push_str(&format!("precond-warmup={}\n", self.precond_warmup));
        s.push_str(&format!("state-dtype={}\n", self.state_dtype.name()));
        s.push_str(&format!("ranks={}\n", self.ranks));
        s.push_str(&format!("dist-timeout={}\n", self.dist_timeout_ms));
        s.push_str(&format!("dist-transport={}\n", self.dist_transport.name()));
        s.push_str(&format!("one-sided={}\n", self.one_sided));
        s.push_str(&format!("factorized={}\n", self.factorized));
        s.push_str(&format!("precondition-1d={}\n", self.precondition_1d));
        s.push_str(&format!("artifacts={}\n", self.artifacts_dir));
        s.push_str(&format!("log-every={}\n", self.log_every));
        s.push_str(&format!("telemetry={}\n", self.telemetry));
        s.push_str(&format!("metrics-every={}\n", self.metrics_every));
        s.push_str(&format!("guard={}\n", self.guard.name()));
        if let Some(plan) = &self.fault_plan {
            s.push_str(&format!("fault-plan={plan}\n"));
        }
        s.push_str(&format!("auto-resume={}\n", self.auto_resume));
        // trace-out / metrics-out / jsonl-out are run actions like
        // save/resume: pass them per invocation, don't bake output paths
        // into a config file. fault-attempt is internal relaunch plumbing.
        s
    }

    /// Build from parsed CLI args, with `--config` layering: CLI-declared
    /// defaults < config file < explicitly typed CLI arguments.
    pub fn from_args(args: &Args) -> anyhow::Result<Self> {
        // A named option that contradicts its legacy flag is rejected rather
        // than silently resolved (unchanged policy).
        if args.flag("refresh-eigh") {
            if let Some(s) = args.get("refresh-method").filter(|s| !s.is_empty()) {
                let method = RefreshMethod::parse(s)?;
                anyhow::ensure!(
                    method == RefreshMethod::Eigh,
                    "--refresh-method {} contradicts --refresh-eigh",
                    method.name()
                );
            }
        }
        if args.flag("async-refresh") {
            if let Some(s) = args.get("refresh-mode").filter(|s| !s.is_empty()) {
                let mode = RefreshMode::parse(s)?;
                anyhow::ensure!(
                    mode == RefreshMode::Async,
                    "--refresh-mode {} contradicts --async-refresh",
                    mode.name()
                );
            }
        }
        if args.flag("pjrt-optimizer") && args.is_explicit("backend") {
            if let Some(s) = args.get("backend") {
                anyhow::ensure!(
                    Backend::parse(s)? == Backend::Pjrt,
                    "--backend {s} contradicts --pjrt-optimizer"
                );
            }
        }

        let mut rc = RunConfig::default();
        // Pass 1: CLI-declared defaults (option present but not typed).
        for key in VALUE_KEYS {
            if !args.is_explicit(key) {
                if let Some(v) = args.get(key).filter(|s| !s.is_empty()) {
                    rc.apply_kv(key, v)?;
                }
            }
        }
        // Pass 2: config file.
        if let Some(path) = args.get("config").filter(|s| !s.is_empty()) {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--config {path}: {e}"))?;
            rc.apply_kv_text(&text)
                .map_err(|e| anyhow::anyhow!("--config {path}: {e:#}"))?;
        }
        // Pass 3: explicitly typed CLI options and flags have the last word.
        for key in VALUE_KEYS {
            if args.is_explicit(key) {
                if let Some(v) = args.get(key).filter(|s| !s.is_empty()) {
                    rc.apply_kv(key, v)?;
                }
            }
        }
        for key in FLAG_KEYS {
            if args.flag(key) {
                rc.apply_kv(key, "true")?;
            }
        }

        // A composition spec that contradicts the legacy variant flags is an
        // error, not a silent tie break.
        if let OptKind::Composed(spec) = &rc.optimizer {
            spec.check_flag_consistency(rc.one_sided, rc.factorized)?;
        }
        rc.validate()?;
        Ok(rc)
    }

    /// Validate the whole configuration: the CLI-level range checks here,
    /// everything structural through the [`SessionBuilder`] seam (one set
    /// of rules for the CLI and the API). Pure — touches no files.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.lr > 0.0 && self.lr < 1.0, "lr out of range (0, 1)");
        // A malformed fault plan fails at launch, not mid-run.
        if let Some(plan) = &self.fault_plan {
            crate::fault::FaultPlan::parse(plan)
                .map_err(|e| anyhow::anyhow!("fault-plan: {e:#}"))?;
        }
        anyhow::ensure!(
            self.auto_resume == 0 || matches!(self.backend, Backend::Distributed { .. }),
            "--auto-resume recovers from distributed peer failures; it needs \
             --backend distributed"
        );
        anyhow::ensure!(
            self.warmup < self.steps || self.warmup == 0,
            "warmup must be < steps"
        );
        // Fail at launch, not after the full run has trained: the pjrt
        // executor has no checkpoint support, so a --save that can only
        // error at the end is rejected here.
        anyhow::ensure!(
            !(self.backend == Backend::Pjrt && self.save.is_some()),
            "--save requires a native backend (serial/sharded); the pjrt executor \
             does not checkpoint"
        );
        if matches!(self.backend, Backend::Distributed { .. }) {
            anyhow::ensure!(
                self.dist_transport == Transport::Tcp,
                "the CLI runs distributed ranks as separate processes, so only the tcp \
                 transport applies here (the mem transport is the in-process API path)"
            );
            anyhow::ensure!(
                self.dist_timeout_ms > 0,
                "dist-timeout must be > 0 milliseconds"
            );
            if self.dist_rank.is_some() {
                anyhow::ensure!(
                    self.coordinator_addr.is_some(),
                    "--rank puts this process in manual worker mode, which needs \
                     --coordinator-addr to find the rendezvous"
                );
            }
        } else {
            anyhow::ensure!(
                self.dist_rank.is_none() && self.coordinator_addr.is_none(),
                "--rank/--coordinator-addr apply to --backend distributed only"
            );
        }
        self.session_builder()?.validate()
    }

    /// The backend with the distributed knobs (`ranks`, `dist-transport`)
    /// resolved in — `Backend::parse` alone only sees the token.
    pub fn resolved_backend(&self) -> Backend {
        match self.backend {
            Backend::Distributed { .. } => {
                Backend::Distributed { ranks: self.ranks, transport: self.dist_transport }
            }
            b => b,
        }
    }

    /// Map onto the typed builder — the single construction path `main.rs`,
    /// benches, and tests share. `resume` is wired in; `save` stays a
    /// launcher action (see `cmd_train`).
    pub fn session_builder(&self) -> anyhow::Result<SessionBuilder> {
        let spec = ModelSpec::parse(&self.model)?;
        let backend = self.resolved_backend();
        let mut b = TrainSession::builder()
            .model(spec)
            .artifacts_dir(&self.artifacts_dir)
            .optimizer(self.optimizer)
            .hyper(self.hyper())
            .schedule(self.schedule())
            .steps(self.steps)
            .seed(self.seed)
            .grad_accum(self.grad_accum)
            .workers(self.workers)
            .backend(backend)
            .log_every(self.log_every)
            .telemetry(self.telemetry)
            .metrics_every(self.metrics_every);
        if let Backend::Distributed { ranks, .. } = backend {
            // Worker mode dials the given coordinator. Coordinator mode gets
            // a placeholder endpoint — `cmd_train` re-attaches DistOptions
            // with the listener it bound before spawning workers — so
            // `validate()` can check the full wiring either way.
            b = b.dist(DistOptions {
                rank: self.dist_rank.unwrap_or(0),
                ranks,
                timeout: Duration::from_millis(self.dist_timeout_ms),
                endpoint: DistEndpoint::Tcp {
                    coordinator: self
                        .coordinator_addr
                        .clone()
                        .unwrap_or_else(|| "127.0.0.1:0".into()),
                    listener: None,
                },
            });
        }
        if let Some(path) = &self.trace_out {
            b = b.trace_out(path);
        }
        if let Some(path) = &self.resume {
            b = b.resume_from(path);
        }
        if let Some(plan) = &self.fault_plan {
            b = b.fault_plan(plan, self.fault_attempt);
        }
        Ok(b)
    }

    pub fn hyper(&self) -> Hyper {
        let mut h = Hyper {
            precond_freq: self.precond_freq,
            precond_freq_schedule: self.precond_freq_schedule,
            precondition_1d: self.precondition_1d,
            one_sided: self.one_sided,
            factorized: self.factorized,
            max_precond_dim: self.max_precond_dim,
            merge_dims: self.merge_dims,
            refresh: if self.refresh_eigh { RefreshMethod::Eigh } else { RefreshMethod::QrPowerIteration },
            refresh_mode: if self.async_refresh { RefreshMode::Async } else { RefreshMode::Inline },
            refresh_workers: self.refresh_workers,
            adam_warmup_steps: self.adam_warmup,
            precondition_warmup: self.precond_warmup,
            state_dtype: self.state_dtype,
            guard: self.guard,
            ..Hyper::default()
        };
        // A composition spec's structural choices (side selection, factored
        // engine, graft activation) override the per-flag knobs, so the
        // resolved Hyper agrees with what the spec will build.
        if let OptKind::Composed(spec) = &self.optimizer {
            spec.apply(&mut h);
        }
        h
    }

    pub fn schedule(&self) -> Schedule {
        if self.warmup > 0 {
            Schedule::paper(self.lr, self.warmup, self.steps)
        } else {
            Schedule::Constant { lr: self.lr }
        }
    }

    /// Legacy mapping onto the pre-redesign [`TrainerConfig`] — kept for the
    /// integration tests that pin the session API to the old `Trainer`.
    pub fn trainer_config(&self) -> TrainerConfig {
        TrainerConfig {
            opt: self.optimizer,
            hyper: self.hyper(),
            schedule: self.schedule(),
            steps: self.steps,
            seed: self.seed,
            grad_accum: self.grad_accum,
            workers: self.workers,
            log_every: self.log_every,
            ..TrainerConfig::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn bad_configs_rejected() {
        let mut rc = RunConfig::default();
        rc.steps = 0;
        assert!(rc.validate().is_err());
        let mut rc = RunConfig::default();
        rc.lr = 2.0;
        assert!(rc.validate().is_err());
        let mut rc = RunConfig::default();
        rc.backend = Backend::Pjrt;
        rc.optimizer = OptKind::Shampoo;
        assert!(rc.validate().is_err());
        // PJRT backend over a native model is structurally impossible.
        let mut rc = RunConfig::default();
        rc.backend = Backend::Pjrt;
        rc.model = "nplm".into();
        assert!(rc.validate().is_err());
        // --save on the pjrt backend would only fail AFTER the run; reject
        // at launch instead.
        let mut rc = RunConfig::default();
        rc.backend = Backend::Pjrt;
        rc.save = Some("run.ckpt".into());
        assert!(rc.validate().is_err());
        // A malformed fault plan fails at launch, not mid-run.
        let mut rc = RunConfig::default();
        rc.fault_plan = Some("drop-frame=2.0".into());
        assert!(rc.validate().is_err());
        // --auto-resume is a distributed recovery knob.
        let mut rc = RunConfig::default();
        rc.auto_resume = 2;
        let e = rc.validate().unwrap_err().to_string();
        assert!(e.contains("distributed"), "{e}");
    }

    #[test]
    fn schedule_selection() {
        let mut rc = RunConfig::default();
        rc.warmup = 10;
        rc.steps = 100;
        match rc.schedule() {
            Schedule::WarmupCosine { warmup, total, .. } => {
                assert_eq!(warmup, 10);
                assert_eq!(total, 100);
            }
            _ => panic!("expected warmup-cosine"),
        }
        rc.warmup = 0;
        assert!(matches!(rc.schedule(), Schedule::Constant { .. }));
    }

    #[test]
    fn hyper_reflects_flags() {
        let mut rc = RunConfig::default();
        rc.one_sided = true;
        rc.refresh_eigh = true;
        rc.precond_freq = 32;
        let h = rc.hyper();
        assert!(h.one_sided);
        assert_eq!(h.refresh, RefreshMethod::Eigh);
        assert_eq!(h.precond_freq, 32);
        assert_eq!(h.refresh_mode, RefreshMode::Inline);

        rc.async_refresh = true;
        rc.refresh_workers = 3;
        let h = rc.hyper();
        assert_eq!(h.refresh_mode, RefreshMode::Async);
        assert_eq!(h.refresh_workers, 3);

        rc.max_precond_dim = 128;
        rc.merge_dims = 256;
        let h = rc.hyper();
        assert_eq!(h.max_precond_dim, 128);
        assert_eq!(h.merge_dims, 256);

        rc.adam_warmup = 40;
        rc.precond_warmup = 6;
        let h = rc.hyper();
        assert_eq!(h.adam_warmup_steps, 40);
        assert_eq!(h.precondition_warmup, 6);
    }

    #[test]
    fn distributed_config_validation() {
        let mut rc = RunConfig::default();
        rc.model = "nplm-tiny".into();
        rc.backend = Backend::parse("distributed").unwrap();
        // Coordinator (self-spawn) mode validates without an address: the
        // launcher binds the listener and fills the endpoint in.
        rc.validate().unwrap();
        assert_eq!(
            rc.resolved_backend(),
            Backend::Distributed { ranks: 2, transport: Transport::Tcp }
        );
        rc.ranks = 4;
        assert!(matches!(rc.resolved_backend(), Backend::Distributed { ranks: 4, .. }));
        rc.validate().unwrap();
        // The mem transport is API-only.
        rc.dist_transport = Transport::Mem;
        let e = rc.validate().unwrap_err().to_string();
        assert!(e.contains("tcp"), "{e}");
        rc.dist_transport = Transport::Tcp;
        // Worker mode needs the rendezvous address.
        rc.dist_rank = Some(1);
        let e = rc.validate().unwrap_err().to_string();
        assert!(e.contains("coordinator-addr"), "{e}");
        rc.coordinator_addr = Some("127.0.0.1:29400".into());
        rc.validate().unwrap();
        // A 1-rank "distributed" run is a config error, not a silent serial.
        rc.ranks = 1;
        assert!(rc.validate().is_err());
        // Launch wiring without the distributed backend is rejected.
        let mut rc = RunConfig::default();
        rc.dist_rank = Some(0);
        rc.coordinator_addr = Some("127.0.0.1:29400".into());
        let e = rc.validate().unwrap_err().to_string();
        assert!(e.contains("--backend distributed"), "{e}");
    }

    #[test]
    fn composed_spec_reaches_hyper() {
        let mut rc = RunConfig::default();
        rc.optimizer = OptKind::parse("basis=eigen:one-sided,inner=adafactor").unwrap();
        rc.validate().unwrap();
        let h = rc.hyper();
        assert!(h.one_sided && h.factorized);
        assert_eq!(rc.optimizer.canonical(), OptKind::Soap);

        // Canonical-to-soap specs pass the PJRT gate; novel combos and
        // adafactor-engine configs (no PJRT artifacts) don't.
        let mut rc = RunConfig::default();
        rc.backend = Backend::Pjrt;
        rc.optimizer = OptKind::parse("basis=eigen,inner=adam").unwrap();
        rc.validate().unwrap();
        rc.optimizer = OptKind::parse("basis=svd,inner=adafactor").unwrap();
        assert!(rc.validate().is_err());
        rc.optimizer = OptKind::parse("basis=eigen,inner=adafactor").unwrap();
        assert!(rc.validate().is_err());
        rc.optimizer = OptKind::Soap;
        rc.factorized = true;
        assert!(rc.validate().is_err());
    }

    #[test]
    fn async_refresh_validation() {
        let mut rc = RunConfig::default();
        rc.async_refresh = true;
        rc.validate().unwrap();
        rc.refresh_workers = 0;
        assert!(rc.validate().is_err());
        let mut rc = RunConfig::default();
        rc.async_refresh = true;
        rc.backend = Backend::Pjrt;
        assert!(rc.validate().is_err());
    }

    #[test]
    fn dump_load_roundtrips_identical_hyper() {
        let mut rc = RunConfig::default();
        rc.model = "nplm".into();
        rc.optimizer = OptKind::parse("basis=eigen:one-sided,inner=adafactor").unwrap();
        rc.backend = Backend::Serial;
        rc.lr = 3.16e-3;
        rc.steps = 123;
        rc.warmup = 17;
        rc.seed = 9;
        // Via apply_kv so the canonical schedule invariant holds (covers step 0,
        // precond_freq mirrors the step-0 frequency).
        rc.apply_kv("precond-freq", "25@0,100@60").unwrap();
        rc.precondition_1d = true;
        rc.grad_accum = 2;
        rc.workers = 3;
        rc.refresh_workers = 4;
        rc.refresh_eigh = true;
        rc.async_refresh = true;
        rc.max_precond_dim = 96;
        rc.merge_dims = 64;
        rc.adam_warmup = 11;
        rc.precond_warmup = 3;
        rc.state_dtype = StateDtype::Bf16;
        rc.ranks = 3;
        rc.dist_timeout_ms = 12_000;
        rc.log_every = 5;
        rc.telemetry = true;
        rc.metrics_every = 7;
        rc.guard = GuardPolicy::Clip(2.5);
        rc.fault_plan = Some("seed=3;drop-frame=0.1".into());
        rc.validate().unwrap();

        let mut back = RunConfig::default();
        back.apply_kv_text(&rc.dump()).unwrap();
        assert_eq!(back.model, rc.model);
        assert_eq!(back.optimizer, rc.optimizer);
        assert_eq!(back.backend, rc.backend);
        assert_eq!(back.lr, rc.lr);
        assert_eq!(back.steps, rc.steps);
        assert_eq!(back.warmup, rc.warmup);
        assert_eq!(back.seed, rc.seed);
        assert_eq!(back.grad_accum, rc.grad_accum);
        assert_eq!(back.workers, rc.workers);
        assert_eq!(back.log_every, rc.log_every);
        assert_eq!(back.telemetry, rc.telemetry);
        assert_eq!(back.metrics_every, rc.metrics_every);
        assert_eq!(back.ranks, rc.ranks);
        assert_eq!(back.dist_timeout_ms, rc.dist_timeout_ms);
        assert_eq!(back.dist_transport, rc.dist_transport);
        assert_eq!(back.guard, rc.guard);
        assert_eq!(back.fault_plan, rc.fault_plan);
        assert_eq!(back.auto_resume, rc.auto_resume);
        assert_eq!(back.precond_freq, 25);
        assert_eq!(back.precond_freq_schedule, rc.precond_freq_schedule);
        assert_eq!(back.state_dtype, rc.state_dtype);
        assert!(back.precondition_1d);
        // The acceptance bar: the resolved Hyper is IDENTICAL.
        let (ha, hb) = (rc.hyper(), back.hyper());
        assert_eq!(format!("{ha:?}"), format!("{hb:?}"), "dump→load changed the Hyper");
        assert!(matches!(back.schedule(), Schedule::WarmupCosine { .. }));
    }

    #[test]
    fn kv_text_rejects_unknown_keys_and_bad_lines() {
        let mut rc = RunConfig::default();
        let e = rc.apply_kv_text("bogus-key=3\n").unwrap_err().to_string();
        assert!(e.contains("bogus-key") && e.contains("model"), "{e}");
        let e = rc.apply_kv_text("no equals sign\n").unwrap_err().to_string();
        assert!(e.contains("line 1"), "{e}");
        // Comments and blanks are fine.
        rc.apply_kv_text("# comment\n\nsteps=50\n").unwrap();
        assert_eq!(rc.steps, 50);
    }

    #[test]
    fn precond_freq_key_accepts_schedules() {
        // Plain number: constant frequency, no schedule.
        let mut rc = RunConfig::default();
        rc.apply_kv("precond-freq", "42").unwrap();
        assert_eq!(rc.precond_freq, 42);
        assert_eq!(rc.precond_freq_schedule, None);

        // Single piece at step 0 folds back to a constant.
        rc.apply_kv("precond-freq", "7@0").unwrap();
        assert_eq!(rc.precond_freq, 7);
        assert_eq!(rc.precond_freq_schedule, None);

        // Multi-piece schedule covering step 0 is kept as-is.
        rc.apply_kv("precond-freq", "10@0,100@1000").unwrap();
        assert_eq!(rc.precond_freq, 10);
        let sched = rc.precond_freq_schedule.expect("schedule");
        assert_eq!(sched.pieces(), &[(0, 10), (1000, 100)]);

        // A schedule that skips step 0 inherits the current base frequency.
        let mut rc = RunConfig::default();
        rc.precond_freq = 5;
        rc.apply_kv("precond-freq", "100@1000").unwrap();
        assert_eq!(rc.precond_freq, 5);
        let sched = rc.precond_freq_schedule.expect("schedule");
        assert_eq!(sched.pieces(), &[(0, 5), (1000, 100)]);
        // And the resolved Hyper switches at the boundary.
        let h = rc.hyper();
        assert_eq!(h.precond_freq_at(999), 5);
        assert_eq!(h.precond_freq_at(1000), 100);

        let e = rc.apply_kv("precond-freq", "ten@0").unwrap_err().to_string();
        assert!(e.contains("precond"), "{e}");
    }

    #[test]
    fn pjrt_optimizer_key_maps_to_backend() {
        let mut rc = RunConfig::default();
        rc.apply_kv("pjrt-optimizer", "true").unwrap();
        assert_eq!(rc.backend, Backend::Pjrt);
        // false does NOT un-pick an explicit backend choice.
        let mut rc = RunConfig::default();
        rc.backend = Backend::Serial;
        rc.apply_kv("pjrt-optimizer", "false").unwrap();
        assert_eq!(rc.backend, Backend::Serial);
    }

    #[test]
    fn session_builder_maps_config() {
        let mut rc = RunConfig::default();
        rc.model = "nplm-tiny".into();
        rc.steps = 4;
        rc.optimizer = OptKind::AdamW;
        let mut session = rc.session_builder().unwrap().build().unwrap();
        let log = session.run().unwrap();
        assert_eq!(log.losses.len(), 4);
        assert!(log.final_loss().is_finite());
    }
}
