//! Run configuration: the launcher surface. Parses CLI options / key=value
//! config files into a validated run description, and owns the
//! paper-default hyperparameter policy (Appendix A).

use crate::coordinator::TrainerConfig;
use crate::optim::{Hyper, OptKind, RefreshMethod, RefreshMode, Schedule};
use crate::util::cli::Args;

/// The learning-rate sweep grid of Appendix A: {.1, .0316, .01, …, 3.16e-4}.
pub const DEFAULT_LRS: [f32; 6] = [0.1, 0.0316, 0.01, 0.00316, 0.001, 0.000316];

/// A fully-resolved run description.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: String,
    pub optimizer: OptKind,
    pub lr: f32,
    pub steps: u64,
    pub warmup: u64,
    pub seed: u64,
    pub precond_freq: u64,
    pub grad_accum: usize,
    pub workers: usize,
    pub one_sided: bool,
    pub factorized: bool,
    pub refresh_eigh: bool,
    /// Run eigenbasis/inverse-root refreshes on the background service
    /// instead of the optimizer hot path (`precond::RefreshService`).
    pub async_refresh: bool,
    /// Worker threads for the async refresh service.
    pub refresh_workers: usize,
    pub pjrt_optimizer: bool,
    pub artifacts_dir: String,
    pub log_every: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            model: "nano".into(),
            optimizer: OptKind::Soap,
            lr: 3e-3,
            steps: 200,
            warmup: 0,
            seed: 0,
            precond_freq: 10,
            grad_accum: 1,
            workers: 4,
            one_sided: false,
            factorized: false,
            refresh_eigh: false,
            async_refresh: false,
            refresh_workers: 2,
            pjrt_optimizer: false,
            artifacts_dir: "artifacts".into(),
            log_every: 10,
        }
    }
}

impl RunConfig {
    /// Build from parsed CLI args (all options optional; see `main.rs` for
    /// the declared option set).
    pub fn from_args(args: &Args) -> anyhow::Result<Self> {
        let mut rc = RunConfig::default();
        if let Some(m) = args.get("model") {
            rc.model = m.to_string();
        }
        if let Some(o) = args.get("optimizer") {
            rc.optimizer = OptKind::parse(o)?;
        }
        if args.get("lr").is_some() {
            rc.lr = args.parse("lr")?;
        }
        if args.get("steps").is_some() {
            rc.steps = args.parse("steps")?;
        }
        if args.get("warmup").is_some() {
            rc.warmup = args.parse("warmup")?;
        }
        if args.get("seed").is_some() {
            rc.seed = args.parse("seed")?;
        }
        if args.get("precond-freq").is_some() {
            rc.precond_freq = args.parse("precond-freq")?;
        }
        if args.get("grad-accum").is_some() {
            rc.grad_accum = args.parse("grad-accum")?;
        }
        if args.get("workers").is_some() {
            rc.workers = args.parse("workers")?;
        }
        if args.get("refresh-workers").is_some() {
            rc.refresh_workers = args.parse("refresh-workers")?;
        }
        // Named forms of the --refresh-eigh / --async-refresh flags; both
        // parse paths enumerate their valid values on error, and a named
        // option that contradicts its legacy flag is rejected rather than
        // silently resolved.
        rc.refresh_eigh = args.flag("refresh-eigh");
        if let Some(s) = args.get("refresh-method").filter(|s| !s.is_empty()) {
            let method = RefreshMethod::parse(s)?;
            anyhow::ensure!(
                !(rc.refresh_eigh && method != RefreshMethod::Eigh),
                "--refresh-method {} contradicts --refresh-eigh",
                method.name()
            );
            rc.refresh_eigh = method == RefreshMethod::Eigh;
        }
        rc.async_refresh = args.flag("async-refresh");
        if let Some(s) = args.get("refresh-mode").filter(|s| !s.is_empty()) {
            let mode = RefreshMode::parse(s)?;
            anyhow::ensure!(
                !(rc.async_refresh && mode != RefreshMode::Async),
                "--refresh-mode {} contradicts --async-refresh",
                mode.name()
            );
            rc.async_refresh = mode == RefreshMode::Async;
        }
        if let Some(d) = args.get("artifacts") {
            rc.artifacts_dir = d.to_string();
        }
        if args.get("log-every").is_some() {
            rc.log_every = args.parse("log-every")?;
        }
        rc.one_sided = args.flag("one-sided");
        rc.factorized = args.flag("factorized");
        rc.pjrt_optimizer = args.flag("pjrt-optimizer");
        // Same policy as the refresh options above: a composition spec that
        // contradicts the legacy variant flags is an error, not a silent tie
        // break.
        if let OptKind::Composed(spec) = &rc.optimizer {
            spec.check_flag_consistency(rc.one_sided, rc.factorized)?;
        }
        rc.validate()?;
        Ok(rc)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.steps > 0, "steps must be > 0");
        anyhow::ensure!(self.precond_freq > 0, "precond-freq must be > 0");
        anyhow::ensure!(self.grad_accum >= 1, "grad-accum must be ≥ 1");
        anyhow::ensure!(self.refresh_workers >= 1, "refresh-workers must be ≥ 1");
        anyhow::ensure!(
            !(self.async_refresh && self.pjrt_optimizer),
            "--async-refresh applies to the native optimizer path (drop --pjrt-optimizer)"
        );
        anyhow::ensure!(self.lr > 0.0 && self.lr < 1.0, "lr out of range (0, 1)");
        anyhow::ensure!(
            self.warmup < self.steps || self.warmup == 0,
            "warmup must be < steps"
        );
        if self.pjrt_optimizer {
            anyhow::ensure!(
                matches!(self.optimizer.canonical(), OptKind::Soap | OptKind::AdamW),
                "--pjrt-optimizer supports soap|adamw (or composition specs canonical to them)"
            );
            // The artifacts only implement the full-V Adam engine; reject
            // factorized/adafactor-engine configs instead of silently
            // running (and mislabeling) the wrong engine.
            anyhow::ensure!(
                !self.hyper().factorized,
                "--pjrt-optimizer runs the full-V SOAP artifacts; the factorized \
                 (adafactor-engine) variant is native-only"
            );
        }
        Ok(())
    }

    pub fn hyper(&self) -> Hyper {
        let mut h = Hyper {
            precond_freq: self.precond_freq,
            one_sided: self.one_sided,
            factorized: self.factorized,
            refresh: if self.refresh_eigh { RefreshMethod::Eigh } else { RefreshMethod::QrPowerIteration },
            refresh_mode: if self.async_refresh { RefreshMode::Async } else { RefreshMode::Inline },
            refresh_workers: self.refresh_workers,
            ..Hyper::default()
        };
        // A composition spec's structural choices (side selection, factored
        // engine, graft activation) override the per-flag knobs, so the
        // resolved Hyper agrees with what the spec will build.
        if let OptKind::Composed(spec) = &self.optimizer {
            spec.apply(&mut h);
        }
        h
    }

    pub fn schedule(&self) -> Schedule {
        if self.warmup > 0 {
            Schedule::paper(self.lr, self.warmup, self.steps)
        } else {
            Schedule::Constant { lr: self.lr }
        }
    }

    pub fn trainer_config(&self) -> TrainerConfig {
        TrainerConfig {
            opt: self.optimizer,
            hyper: self.hyper(),
            schedule: self.schedule(),
            steps: self.steps,
            seed: self.seed,
            grad_accum: self.grad_accum,
            workers: self.workers,
            log_every: self.log_every,
            ..TrainerConfig::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn bad_configs_rejected() {
        let mut rc = RunConfig::default();
        rc.steps = 0;
        assert!(rc.validate().is_err());
        let mut rc = RunConfig::default();
        rc.lr = 2.0;
        assert!(rc.validate().is_err());
        let mut rc = RunConfig::default();
        rc.pjrt_optimizer = true;
        rc.optimizer = OptKind::Shampoo;
        assert!(rc.validate().is_err());
    }

    #[test]
    fn schedule_selection() {
        let mut rc = RunConfig::default();
        rc.warmup = 10;
        rc.steps = 100;
        match rc.schedule() {
            Schedule::WarmupCosine { warmup, total, .. } => {
                assert_eq!(warmup, 10);
                assert_eq!(total, 100);
            }
            _ => panic!("expected warmup-cosine"),
        }
        rc.warmup = 0;
        assert!(matches!(rc.schedule(), Schedule::Constant { .. }));
    }

    #[test]
    fn hyper_reflects_flags() {
        let mut rc = RunConfig::default();
        rc.one_sided = true;
        rc.refresh_eigh = true;
        rc.precond_freq = 32;
        let h = rc.hyper();
        assert!(h.one_sided);
        assert_eq!(h.refresh, RefreshMethod::Eigh);
        assert_eq!(h.precond_freq, 32);
        assert_eq!(h.refresh_mode, RefreshMode::Inline);

        rc.async_refresh = true;
        rc.refresh_workers = 3;
        let h = rc.hyper();
        assert_eq!(h.refresh_mode, RefreshMode::Async);
        assert_eq!(h.refresh_workers, 3);
    }

    #[test]
    fn composed_spec_reaches_hyper() {
        let mut rc = RunConfig::default();
        rc.optimizer = OptKind::parse("basis=eigen:one-sided,inner=adafactor").unwrap();
        rc.validate().unwrap();
        let h = rc.hyper();
        assert!(h.one_sided && h.factorized);
        assert_eq!(rc.optimizer.canonical(), OptKind::Soap);

        // Canonical-to-soap specs pass the PJRT gate; novel combos and
        // adafactor-engine configs (no PJRT artifacts) don't.
        let mut rc = RunConfig::default();
        rc.pjrt_optimizer = true;
        rc.optimizer = OptKind::parse("basis=eigen,inner=adam").unwrap();
        rc.validate().unwrap();
        rc.optimizer = OptKind::parse("basis=svd,inner=adafactor").unwrap();
        assert!(rc.validate().is_err());
        rc.optimizer = OptKind::parse("basis=eigen,inner=adafactor").unwrap();
        assert!(rc.validate().is_err());
        rc.optimizer = OptKind::Soap;
        rc.factorized = true;
        assert!(rc.validate().is_err());
    }

    #[test]
    fn async_refresh_validation() {
        let mut rc = RunConfig::default();
        rc.async_refresh = true;
        rc.validate().unwrap();
        rc.refresh_workers = 0;
        assert!(rc.validate().is_err());
        let mut rc = RunConfig::default();
        rc.async_refresh = true;
        rc.pjrt_optimizer = true;
        assert!(rc.validate().is_err());
    }
}
